//! Volta/V100 experiment reports (Fig 8, Table 3, Table 5, Fig 11).

use crate::benchkit::{ms, x, Table};
use crate::coordinator::offload::{layer_latency_model, measured_cpu_attention, plan};
use crate::models::{self};
use crate::sim::memory::Deployment;
use crate::sim::volta::{VoltaKernel, VoltaSpec};
use crate::sim::AttnWorkload;

/// Fig 8: FastAttention vs xformers FlashAttention on one V100
/// (B=8, hidden 2048, 64 heads), in achieved TFLOPs/s.
pub fn fig8_xformers() -> Table {
    let spec = VoltaSpec::default();
    let mut t = Table::new(
        "Fig 8 — V100 TFLOPs/s vs xformers (paper: 1.03–1.17× no-causal; ≤1.43× causal)",
        &["causal", "seq", "xformers TF/s", "fastattn TF/s", "speedup", "paper"],
    );
    let paper_nc: &[(u64, f64)] =
        &[(2048, 1.03), (4096, 1.06), (8192, 1.12), (16384, 1.17)];
    for &(s, p) in paper_nc {
        let w = AttnWorkload::prefill(8, 64, s, 32, false);
        let xf = spec.attention_tflops(VoltaKernel::Xformers, &w);
        let fa = spec.attention_tflops(VoltaKernel::FastAttention, &w);
        t.row(&[
            "no".into(),
            format!("{}K", s / 1024),
            format!("{xf:.1}"),
            format!("{fa:.1}"),
            x(fa / xf),
            x(p),
        ]);
    }
    for s in [2048u64, 4096, 8192, 16384] {
        let w = AttnWorkload::prefill(8, 64, s, 32, true);
        let xf = spec.attention_tflops(VoltaKernel::Xformers, &w);
        let fa = spec.attention_tflops(VoltaKernel::FastAttention, &w);
        let paper = if s == 16384 { "1.43×" } else { "—" };
        t.row(&[
            "yes".into(),
            format!("{}K", s / 1024),
            format!("{xf:.1}"),
            format!("{fa:.1}"),
            x(fa / xf),
            paper.into(),
        ]);
    }
    t
}

/// Table 3: CPU–GPU cooperative strategy vs classical offloading,
/// PanGu-38B on 8× V100, per-layer decode attention breakdown.
pub fn table3_offload() -> Table {
    let spec = VoltaSpec::default();
    let model = models::PANGU_38B;
    let mut t = Table::new(
        "Table 3 — offload breakdown, PanGu-38B 8×V100 (paper totals: classical 3.892→54.92 ms; coop 2.719→37.806 ms)",
        &[
            "seq",
            "upload (ms)",
            "GPU calc (ms)",
            "classical (ms)",
            "CPU calc (ms)",
            "off-upload (ms)",
            "coop (ms)",
            "speedup",
            "paper speedup",
            "live CPU (ms)",
        ],
    );
    let paper: &[(u64, f64, f64)] = &[
        (16 * 1024, 3.892, 2.719),
        (32 * 1024, 7.548, 5.345),
        (64 * 1024, 13.66, 10.685),
        (128 * 1024, 27.698, 18.721),
        (256 * 1024, 54.92, 37.806),
    ];
    // Short rows (no offload) first, as in the paper.
    for s in [1024u64, 2048, 4096, 8192] {
        let per = layer_latency_model(&spec, &model, 8, 1, s);
        t.row(&[
            format!("{}K", s / 1024),
            "—".into(),
            ms(per.gpu_calc_s),
            ms(per.gpu_calc_s),
            "—".into(),
            "—".into(),
            ms(per.gpu_calc_s),
            "1.00×".into(),
            "—".into(),
            "—".into(),
        ]);
    }
    for &(s, pc, pf) in paper {
        let per = layer_latency_model(&spec, &model, 8, 1, s);
        // live host attention on this machine for the same shard shape
        let live = measured_cpu_attention(5, s as usize, 128);
        t.row(&[
            format!("{}K", s / 1024),
            ms(per.upload_s),
            ms(per.gpu_calc_s),
            ms(per.classical_total()),
            ms(per.cpu_calc_s),
            ms(per.off_upload_s),
            ms(per.coop_total()),
            x(per.classical_total() / per.coop_total()),
            x(pc / pf),
            ms(live),
        ]);
    }
    t
}

/// Table 5: torch-DeepSpeed baseline on 8× V100 (no CUDA graphs — per-op
/// launch overhead dominates).
pub fn table5_deepspeed() -> Table {
    let spec = VoltaSpec::default();
    let mut t = Table::new(
        "Table 5 — DeepSpeed (torch) on 8× V100 (paper: OPT-30B 270→692 ms; LLaMA-65B 513→3849 ms; N/A beyond limits)",
        &["model", "seq", "latency (ms)", "paper (ms)", "tok/s", "paper tok/s"],
    );
    let paper: &[(&str, u64, Option<(f64, f64)>)] = &[
        ("OPT-30B", 512, Some((270.5, 20.25))),
        ("OPT-30B", 1024, Some((384.74, 16.27))),
        ("OPT-30B", 2048, Some((691.67, 11.59))),
        ("OPT-30B", 4096, None),
        ("LLaMA-65B", 512, Some((513.15, 10.57))),
        ("LLaMA-65B", 1024, Some((1046.79, 6.73))),
        ("LLaMA-65B", 2048, Some((2206.95, 4.08))),
        ("LLaMA-65B", 4096, Some((3848.61, 2.35))),
        ("LLaMA-65B", 8192, None),
    ];
    for &(name, s, p) in paper {
        let model = models::by_name(name).unwrap();
        // model limit: OPT-30B has a 2K context; LLaMA-65B 4K (paper N/A)
        let limit = if name == "OPT-30B" { 2048 } else { 4096 };
        if s > limit {
            t.row(&[
                name.into(),
                format!("{s}"),
                "N/A".into(),
                "N/A".into(),
                "N/A".into(),
                "N/A".into(),
            ]);
            continue;
        }
        // torch DeepSpeed latency: per-layer GEMMs + attention + per-op
        // launch overhead × ~14 unfused ops/layer, + allreduce.
        let h1 = model.hidden();
        let h2 = model.ffn as u64;
        let shard = 8;
        let per_layer = spec.gemm(s, h1, h1 * 4 / shard)
            + spec.gemm(s, h1, 2 * h2 / shard)
            + spec.attention_latency(
                VoltaKernel::Xformers,
                &AttnWorkload::prefill(1, (model.heads / 8) as u64, s, model.head_dim as u64, true),
            )
            + 14.0 * spec.torch_op_overhead_s
            + spec.allreduce(2 * s * h1, 8);
        let latency = per_layer * model.layers as f64;
        // decode throughput: weight-bound GEMV + overheads per layer
        let w_bytes = 2.0 * (4 * h1 * h1 + 2 * h1 * h2) as f64 / 8.0;
        let dec_layer = w_bytes / spec.hbm_bw
            + 14.0 * spec.torch_op_overhead_s
            + spec.allreduce(2 * h1, 8)
            + spec.decode_attention_gpu(model.kv_bytes_per_layer_fp16(1, s, 8));
        let tps = 1.0 / (dec_layer * model.layers as f64);
        let (pl, pt) = p.map(|(a, b)| (format!("{a:.1}"), format!("{b:.2}")))
            .unwrap_or(("N/A".into(), "N/A".into()));
        t.row(&[
            name.into(),
            format!("{s}"),
            ms(latency),
            pl,
            format!("{tps:.2}"),
            pt,
        ]);
    }
    t
}

/// Fig 11: FasterTransformer ± FastAttention on 8× V100 — latency and
/// max context (16K → 256K).
pub fn fig11_ft_v100() -> Table {
    let spec = VoltaSpec::default();
    let mut t = Table::new(
        "Fig 11 — FT ± FastAttention, 8×V100 (paper: ≤1.46× PanGu-38B, ≤1.28× PanGu-71B; 16K→256K)",
        &["model", "seq", "FT (ms)", "FT+FastAttn (ms)", "speedup", "note"],
    );
    for model in [models::PANGU_38B, models::PANGU_71B] {
        // PanGu-71B's 142 GB of fp16 weights need the 32 GB V100 variant;
        // PanGu-38B runs on the 16 GB one (which yields the paper's ~16K
        // baseline ceiling).
        let mut dep0 = Deployment::v100_node(model, 0, 50);
        if model.params > 60_000_000_000 {
            dep0.gpu_mem_bytes = 32 << 30;
        }
        let base_max = dep0.max_seq_without_offload();
        let coop_max = dep0.max_seq_with_offload(768 * (1u64 << 30));
        for s in [1024u64, 4096, 16384, 65536, 262144] {
            let heads = (model.heads / 8) as u64;
            let w = AttnWorkload::prefill(1, heads, s, model.head_dim as u64, true);
            let h1 = model.hidden();
            let h2 = model.ffn as u64;
            let linear = spec.gemm(s, h1, (4 * h1 + 2 * h2) / 8);
            let comm = spec.allreduce(2 * s * h1, 8);

            let dep = Deployment { seq: s, ..dep0 };
            let p = plan(&dep);
            let per = layer_latency_model(&spec, &model, 8, 1, s);

            // FastAttention path latency (prefill-dominated one-token):
            let fast_attn = spec.attention_latency(VoltaKernel::FastAttention, &w);
            let fast = (fast_attn + linear + comm) * model.layers as f64
                + p.l_cpu as f64 * per.off_upload_s;

            if s > base_max {
                let note = if s <= coop_max {
                    format!("baseline OOM (max {}K)", base_max / 1024)
                } else {
                    "beyond both".into()
                };
                t.row(&[
                    model.name.into(),
                    format!("{}K", s / 1024),
                    "N/A".into(),
                    ms(fast),
                    "∞".into(),
                    note,
                ]);
            } else {
                let base_attn = spec.attention_latency(VoltaKernel::Xformers, &w);
                // The FT baseline attention is not a flash kernel: it
                // materializes the S×S scores (write + read) and streams
                // the S×S mask from HBM.
                let mask_io = 3.0 * w.score_bytes(2) as f64 / spec.hbm_bw;
                let base = (base_attn + mask_io + linear + comm) * model.layers as f64;
                t.row(&[
                    model.name.into(),
                    format!("{}K", s / 1024),
                    ms(base),
                    ms(fast),
                    x(base / fast),
                    String::new(),
                ]);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_speedups_in_band() {
        let spec = VoltaSpec::default();
        for s in [16 * 1024u64, 256 * 1024] {
            let per = layer_latency_model(&spec, &models::PANGU_38B, 8, 1, s);
            let sp = per.classical_total() / per.coop_total();
            assert!(sp > 1.2 && sp < 1.7, "S={s}: {sp}");
        }
    }

    #[test]
    fn fig11_fastattn_extends_context() {
        let dep = Deployment::v100_node(models::PANGU_38B, 0, 50);
        assert!(dep.max_seq_without_offload() < 64 * 1024);
        assert!(dep.max_seq_with_offload(768 << 30) >= 256 * 1024);
    }

    #[test]
    fn all_volta_tables_render() {
        fig8_xformers().print();
        table3_offload().print();
        table5_deepspeed().print();
        fig11_ft_v100().print();
    }
}
