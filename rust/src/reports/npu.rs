//! Ascend-NPU experiment reports (Figs 7, 9, 10; Tables 2, 4, 6, 7, 8, 9).

use std::time::Instant;

use crate::attention::batch::{
    batch_decode_attention, BatchShape, ParallelConfig, SeqAttn, SeqKv, WorkPool,
};
use crate::benchkit::{ms, x, Table};
use crate::coordinator::kv_cache::{BlockTable, CacheShape, PageCodec, PagePool};
use crate::models::{self, ModelShape};
use crate::sim::ascend::{AscendSpec, FastAttnOptions, Tiling};
use crate::sim::collective::{
    best_block_count, make_blocks, serial_schedule, RingSpec,
};
use crate::sim::AttnWorkload;

/// §5.2.1 shapes: per-NPU head counts on one 910B.
fn pangu38_w(s: u64) -> AttnWorkload {
    AttnWorkload::prefill(1, 5, s, 128, true)
}

fn pangu71_w(s: u64) -> AttnWorkload {
    AttnWorkload::prefill(1, 4, s, 128, true)
}

/// Fig 7: FastAttention vs standard attention on one Ascend 910B.
pub fn fig7_single_npu() -> Table {
    let spec = AscendSpec::default();
    let opts = FastAttnOptions::default();
    let mut t = Table::new(
        "Fig 7 — FastAttention vs standard attention, 1× Ascend 910B (paper: up to 10.7× / 7.1×)",
        &["model", "seq", "standard (ms)", "fastattn (ms)", "speedup", "paper-band"],
    );
    for (name, mk, band) in [
        ("PanGu-38B", pangu38_w as fn(u64) -> AttnWorkload, "4.85–10.7×"),
        ("PanGu-71B", pangu71_w as fn(u64) -> AttnWorkload, "≤7.1×"),
    ] {
        for s in [1024u64, 2048, 4096, 8192, 16384] {
            let w = mk(s);
            let std = spec.standard_attention_latency(&w);
            let fast = spec.fastattn_latency(&w, &opts).latency_s;
            t.row(&[
                name.into(),
                format!("{}K", s / 1024),
                ms(std),
                ms(fast),
                x(std / fast),
                band.into(),
            ]);
        }
    }
    t
}

/// Fig 9: two-level first-level block-size sweep vs the BS=128 baseline.
pub fn fig9_blocksize_sweep() -> Table {
    let spec = AscendSpec::default();
    let mut t = Table::new(
        "Fig 9 — first-level block-size sweep (baseline BS=128; paper: −26…−45% at ≥4K)",
        &["model", "seq", "BS=128 (ms)", "BS=256 (ms)", "BS=512 (ms)", "Δ512 vs 128", "paper Δ"],
    );
    let paper: &[(&str, u64, &str)] = &[
        ("PanGu-38B", 4096, "−26%"),
        ("PanGu-38B", 8192, "−33%"),
        ("PanGu-38B", 16384, "−38%"),
        ("PanGu-71B", 4096, "−37%"),
        ("PanGu-71B", 8192, "−43%"),
        ("PanGu-71B", 16384, "−45%"),
    ];
    for (name, mk) in [
        ("PanGu-38B", pangu38_w as fn(u64) -> AttnWorkload),
        ("PanGu-71B", pangu71_w as fn(u64) -> AttnWorkload),
    ] {
        for s in [1024u64, 4096, 8192, 16384] {
            let w = mk(s);
            let lat = |b1: u64| {
                spec.fastattn_latency(
                    &w,
                    &FastAttnOptions {
                        tiling: Tiling::TwoLevel { block1: b1, block2: 128.min(b1) },
                        ..Default::default()
                    },
                )
                .latency_s
            };
            let (l128, l256, l512) = (lat(128), lat(256), lat(512));
            let delta = format!("{:+.0}%", (l512 / l128 - 1.0) * 100.0);
            let paper_d = paper
                .iter()
                .find(|(n, ps, _)| *n == name && *ps == s)
                .map(|(_, _, d)| *d)
                .unwrap_or("—");
            t.row(&[
                name.into(),
                format!("{}K", s / 1024),
                ms(l128),
                ms(l256),
                ms(l512),
                delta,
                paper_d.into(),
            ]);
        }
    }
    t
}

/// Per-device fused attention+Linear time and AllReduce bytes for one
/// prefill of `model` over `n` devices.
fn layer_compute_and_bytes(
    spec: &AscendSpec,
    model: &ModelShape,
    s: u64,
    n: u64,
) -> (f64, u64) {
    let heads_dev = model.heads_per_device(n as u32) as u64;
    let w = AttnWorkload::prefill(1, heads_dev, s, model.head_dim as u64, true);
    let attn = spec.fastattn_latency(&w, &FastAttnOptions::default()).latency_s;
    let linear = spec.linear_latency(s, model.hidden(), model.ffn as u64, n, 2, true);
    let bytes = 2 * s * model.hidden(); // fp16 activations B·S×H1
    (attn + linear, bytes)
}

/// Fig 10: fused FastAttention + tiling-AllReduce vs unfused baseline on
/// eight 910B NPUs.
pub fn fig10_multi_npu() -> Table {
    let spec = AscendSpec::default();
    let ring = RingSpec::default();
    let mut t = Table::new(
        "Fig 10 — 8× Ascend 910B: fused + tiling-AllReduce vs unfused (paper: 1.16–1.40× PanGu-38B, 7.4–26.1% PanGu-71B, ≤1.3× LLaMA2-70B)",
        &["model", "seq", "unfused (ms)", "fastattn (ms)", "speedup", "paper-band"],
    );
    for (model, band) in [
        (models::PANGU_38B, "1.16–1.40×"),
        (models::PANGU_71B, "1.07–1.26×"),
        (models::LLAMA2_70B, "≤1.3×"),
    ] {
        for s in [2048u64, 4096, 8192, 16384, 32768] {
            let (compute, bytes) = layer_compute_and_bytes(&spec, &model, s, 8);
            // unfused baseline: separate kernels (extra launches + GM
            // round trip of the attention output) then a blocking AllReduce
            let unfused_extra = 4.0 * spec.op_launch_s
                + (2 * s * model.hidden()) as f64 * 2.0 / spec.gm_bw;
            let serial =
                compute + unfused_extra + serial_schedule(&ring, &make_blocks(bytes, 0.0, 1, 1.0));
            let (nb, overlapped) = best_block_count(&ring, bytes, compute);
            let _ = nb;
            t.row(&[
                model.name.into(),
                format!("{}K", s / 1024),
                ms(serial),
                ms(overlapped),
                x(serial / overlapped),
                band.into(),
            ]);
        }
    }
    t
}

/// Table 2: strategy ablation on NPUs.
pub fn table2_ablation() -> Table {
    let spec = AscendSpec::default();
    let ring = RingSpec::default();
    let mut t = Table::new(
        "Table 2 — ablation (speedup vs standard attention, min–max over S = 1K…16K)",
        &["configuration", "measured", "paper"],
    );
    let seqs = [1024u64, 2048, 4096, 8192, 16384];

    let range = |f: &dyn Fn(u64) -> f64| -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for &s in &seqs {
            let v = f(s);
            lo = lo.min(v);
            hi = hi.max(v);
        }
        (lo, hi)
    };
    let std = |s: u64| spec.standard_attention_latency(&pangu38_w(s));

    // unified tiling only
    let (ulo, uhi) = range(&|s| {
        std(s)
            / spec
                .fastattn_latency(
                    &pangu38_w(s),
                    &FastAttnOptions {
                        tiling: Tiling::Unified { block: 128 },
                        tiling_mask: false,
                        elem_bytes: 2,
                    },
                )
                .latency_s
    });
    // two-level
    let (tlo, thi) = range(&|s| {
        std(s)
            / spec
                .fastattn_latency(
                    &pangu38_w(s),
                    &FastAttnOptions { tiling_mask: false, ..Default::default() },
                )
                .latency_s
    });
    // two-level + tiling-AllReduce: the paper compounds the single-NPU
    // kernel speedup with the multi-NPU overlap gain (Fig 10 style), so
    // this row is two_level(s) x overlap_gain(s).
    let (alo, ahi) = range(&|s| {
        let model = models::PANGU_38B;
        let (compute, bytes) = layer_compute_and_bytes(&spec, &model, s, 8);
        let serial = compute + serial_schedule(&ring, &make_blocks(bytes, 0.0, 1, 1.0));
        let (_, fast) = best_block_count(&ring, bytes, compute);
        let overlap_gain = serial / fast;
        let two_level = std(s)
            / spec
                .fastattn_latency(
                    &pangu38_w(s),
                    &FastAttnOptions { tiling_mask: false, ..Default::default() },
                )
                .latency_s;
        two_level * overlap_gain
    });
    // tiling-mask alone: memory-saving, no speedup vs standard (paper: 1×)
    t.row(&["tiling-mask only".into(), "1.00× (memory-saving)".into(), "1×".into()]);
    t.row(&[
        "unified tiling".into(),
        format!("{:.2}–{:.2}×", ulo, uhi),
        "2.55–7×".into(),
    ]);
    t.row(&[
        "two-level tiling".into(),
        format!("{:.2}–{:.2}×", tlo, thi),
        "3.65–10.7×".into(),
    ]);
    t.row(&[
        "two-level + tiling-AllReduce".into(),
        format!("{:.2}–{:.2}×", alo, ahi),
        "4.23–15×".into(),
    ]);
    t.row(&[
        "+ tiling-mask (same speed, −mask memory)".into(),
        format!("{:.2}–{:.2}×", alo, ahi),
        "4.23–15×".into(),
    ]);
    t
}

/// End-to-end one-token latency for a prefill of `s` over `n` NPUs.
fn e2e_prefill_latency(spec: &AscendSpec, model: &ModelShape, s: u64, n: u64) -> f64 {
    let ring = RingSpec::default();
    let (compute, bytes) = layer_compute_and_bytes(spec, model, s, n);
    let (_, layer) = best_block_count(&ring, bytes, compute);
    layer * model.layers as f64
}

/// Per-token decode latency at context `s` over `n` NPUs.
fn e2e_decode_latency(spec: &AscendSpec, model: &ModelShape, s: u64, n: u64) -> f64 {
    let ring = RingSpec::default();
    let heads_dev = model.heads_per_device(n as u32) as u64;
    let per_layer = spec.layer_decode_latency(
        1,
        heads_dev,
        s,
        model.head_dim as u64,
        model.hidden(),
        model.ffn as u64,
        n,
        2,
        true,
        false,
    ) + ring.allreduce(2 * model.hidden());
    per_layer * model.layers as f64
}

/// Table 4: end-to-end latency + throughput on 8× Ascend 910B.
pub fn table4_e2e() -> Table {
    let spec = AscendSpec::default();
    let mut t = Table::new(
        "Table 4 — E2E on 8× Ascend 910B (paper: PanGu-38B 240.81/292.33/1393.42 ms, 95/88/76 tok/s)",
        &["model", "seq", "latency (ms)", "paper (ms)", "tok/s", "paper tok/s"],
    );
    let paper: &[(&str, u64, f64, u64)] = &[
        ("PanGu-38B", 4096, 240.81, 95),
        ("PanGu-38B", 8192, 292.33, 88),
        ("PanGu-38B", 32768, 1393.42, 76),
        ("PanGu-71B", 4096, 539.14, 34),
        ("PanGu-71B", 8192, 1052.49, 33),
        ("PanGu-71B", 32768, 4948.33, 25),
    ];
    for (model, pname) in [(models::PANGU_38B, "PanGu-38B"), (models::PANGU_71B, "PanGu-71B")] {
        for s in [4096u64, 8192, 32768] {
            // latency = time to produce one token = prefill pass
            let latency = e2e_prefill_latency(&spec, &model, s, 8);
            // throughput: 50 tokens decoded at growing context
            let mut decode_t = 0.0;
            for i in 0..50u64 {
                decode_t += e2e_decode_latency(&spec, &model, s + i, 8);
            }
            let tput = 50.0 / decode_t;
            let (pl, pt) = paper
                .iter()
                .find(|(n, ps, _, _)| *n == pname && *ps == s)
                .map(|(_, _, l, t)| (*l, *t))
                .unwrap();
            t.row(&[
                pname.into(),
                format!("{}K", s / 1024),
                ms(latency),
                format!("{pl:.2}"),
                format!("{tput:.0}"),
                format!("{pt}"),
            ]);
        }
    }
    t
}

/// Table 6: throughput with/without FastAttention on one 910B
/// (LLaMA2-7B, prompt 512, generate 50).
pub fn table6_throughput() -> Table {
    let spec = AscendSpec::default();
    let model = models::LLAMA2_7B;
    let mut t = Table::new(
        "Table 6 — LLaMA2-7B throughput on 1× Ascend 910B (paper: 11.03→56.97, 91.6→436, 158→746 tok/s)",
        &["batch", "standard (tok/s)", "fastattn (tok/s)", "speedup", "paper speedup"],
    );
    let paper: &[(u64, f64, f64)] =
        &[(1, 11.03, 56.974), (8, 91.61, 436.1), (16, 158.34, 746.27)];
    for &(b, pstd, pfast) in paper {
        let decode = |fused: bool| {
            let mut total = 0.0;
            for i in 0..50u64 {
                total += spec.layer_decode_latency(
                    b,
                    model.heads as u64,
                    512 + i,
                    model.head_dim as u64,
                    model.hidden(),
                    model.ffn as u64,
                    1,
                    2,
                    fused,
                    true,
                ) * model.layers as f64;
            }
            (50 * b) as f64 / total
        };
        let std_tps = decode(false);
        let fast_tps = decode(true);
        t.row(&[
            format!("{b}"),
            format!("{std_tps:.1}"),
            format!("{fast_tps:.1}"),
            x(fast_tps / std_tps),
            x(pfast / pstd),
        ]);
    }
    t
}

/// Table 7: ViT/DeiT per-op time breakdown (attention is NOT the
/// bottleneck — why ViTs are out of FastAttention's target scope).
pub fn table7_vit_breakdown() -> Table {
    let spec = AscendSpec::default();
    let mut t = Table::new(
        "Table 7 — ViT computation breakdown (paper: attention 4–14% of total)",
        &["model", "seq", "QKV proj", "attention", "O proj", "MLP", "paper attn%"],
    );
    for (model, s, paper_attn) in [
        (models::VIT_B, 577u64, "11%"),
        (models::VIT_B, 197, "4%"),
        (models::DEIT_S, 197, "8%"),
        (models::DEIT_TI, 197, "14%"),
    ] {
        let h1 = model.hidden();
        let h2 = model.ffn as u64;
        let b = 64u64; // inference batch
        let gemm = |flops: f64, bytes: f64| -> f64 {
            (flops / (spec.cube_flops_fp16 * spec.cube_eff)).max(bytes / spec.gm_bw)
                + spec.op_launch_s
        };
        let tok = (b * s) as f64;
        let qkv = gemm(2.0 * tok * 3.0 * (h1 * h1) as f64, (3 * h1 * h1 * 2) as f64);
        let w = AttnWorkload::prefill(b, model.heads as u64, s, model.head_dim as u64, false);
        // Breakdown of the deployed model: attention runs as one fused op
        // (the paper profiles a tuned inference stack, where attention is
        // 4-14% of the layer, not the unfused naive baseline).
        let attn = spec.fastattn_latency(&w, &FastAttnOptions::default()).latency_s;
        let oproj = gemm(2.0 * tok * (h1 * h1) as f64, (h1 * h1 * 2) as f64);
        let mlp = gemm(2.0 * tok * 2.0 * (h1 * h2) as f64, (2 * h1 * h2 * 2) as f64);
        let total = qkv + attn + oproj + mlp;
        let pct = |v: f64| format!("{:.0}%", v / total * 100.0);
        let label = if s == 577 { format!("{}/384", model.name) } else { model.name.to_string() };
        t.row(&[
            label,
            format!("{s}"),
            pct(qkv),
            pct(attn),
            pct(oproj),
            pct(mlp),
            paper_attn.into(),
        ]);
    }
    t
}

/// Table 8: DeiT-B single-operator speedups across batch sizes.
pub fn table8_deit() -> Table {
    let spec = AscendSpec::default();
    let model = models::DEIT_B;
    let mut t = Table::new(
        "Table 8 — DeiT-B attention operator on 1× Ascend 910B (paper: 2.52–7.58×)",
        &["batch", "standard (ms)", "fastattn (ms)", "speedup", "paper"],
    );
    let paper: &[(u64, f64)] = &[
        (32, 2.52),
        (64, 4.62),
        (128, 5.68),
        (256, 6.664),
        (512, 6.89),
        (1024, 7.58),
    ];
    for &(b, pspeed) in paper {
        let w = AttnWorkload::prefill(b, model.heads as u64, 197, 64, false);
        let std = spec.standard_attention_latency(&w);
        let fast = spec
            .fastattn_latency(&w, &FastAttnOptions::default())
            .latency_s;
        t.row(&[
            format!("{b}"),
            ms(std),
            ms(fast),
            x(std / fast),
            x(pspeed),
        ]);
    }
    t
}

/// One measured single-token paged decode on the host kernel: a b=1
/// `batch_decode_attention` pass over `codec`-encoded pages holding
/// `seq` deterministic cached tokens.  Returns the best-of-`iters`
/// seconds (after one warmup) and the attention output, so callers can
/// cross-check codec parity as well as time.
pub fn host_paged_decode(
    seq: usize,
    heads: usize,
    head_dim: usize,
    codec: PageCodec,
    iters: usize,
) -> (f64, Vec<f32>) {
    let page_size = 16;
    let sh = CacheShape { layers: 1, kv_heads: heads, max_seq: seq, head_dim };
    let mut pool = PagePool::with_codec(
        page_size,
        head_dim,
        BlockTable::pages_needed(sh, page_size, seq),
        codec,
    );
    let mut table = BlockTable::new(sh, page_size);
    table.ensure_capacity(seq, &mut pool).expect("pool sized for seq");
    // deterministic pseudo-values in [-1, 1) — identical across codecs
    let val = |i: usize| (i.wrapping_mul(2654435761) % 1997) as f32 / 998.5 - 1.0;
    let mut k_row = vec![0.0f32; head_dim];
    let mut v_row = vec![0.0f32; head_dim];
    for g in 0..heads {
        for r in 0..seq {
            for t in 0..head_dim {
                let i = (g * seq + r) * head_dim + t;
                k_row[t] = val(i);
                v_row[t] = val(i ^ 0x5bd1e995);
            }
            let (page, slot) = table.locate(0, g, r);
            pool.write_row(page, slot, &k_row, &v_row);
        }
    }
    let pages = table.layer_pages(0);
    let kv = match codec {
        PageCodec::F32 => SeqKv::Paged {
            k_store: pool.k_store(),
            v_store: pool.v_store(),
            pages,
            max_blocks: table.max_blocks(),
            page_size,
        },
        PageCodec::Int8 => SeqKv::PagedI8 {
            k: pool.k_quant_store(),
            v: pool.v_quant_store(),
            pages,
            max_blocks: table.max_blocks(),
            page_size,
        },
    };
    let q: Vec<f32> = (0..heads * head_dim).map(val).collect();
    let seqs = [SeqAttn { q: &q, kv, kv_len: seq }];
    let shape = BatchShape::new(heads, heads, head_dim, seq);
    let wp = WorkPool::new(ParallelConfig::sequential());
    let mut out = vec![0.0f32; heads * head_dim];
    batch_decode_attention(&shape, &seqs, &mut out, &wp); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        batch_decode_attention(&shape, &seqs, &mut out, &wp);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    (best, out)
}

/// Table 9: FP16 vs INT8 FastAttention decode on PanGu-71B — the
/// analytic Ascend `elem_bytes` model next to a *measured* host-kernel
/// fp32-vs-int8 paged decode at the same per-device shape (int8 rows
/// dequantized fused in the gather).  `FASTATTN_SMOKE=1` — and any
/// debug (unoptimized) build — caps the measured sweep at seq 512 so
/// smoke CI and `cargo test` stay quick.
pub fn table9_quant() -> Table {
    let spec = AscendSpec::default();
    let model = models::PANGU_71B;
    let mut t = Table::new(
        "Table 9 — FastAttention FP16 vs INT8, PanGu-71B decode (paper: ~0.99–1.29×)",
        &["seq", "fp16 (µs)", "int8 (µs)", "speedup", "paper", "host f32 (µs)", "host i8 (µs)", "host ×"],
    );
    let paper: &[(u64, f64)] = &[
        (128, 1.286),
        (256, 1.153),
        (512, 0.987),
        (1024, 1.247),
        (2048, 1.214),
        (4096, 1.26),
    ];
    let smoke = std::env::var("FASTATTN_SMOKE").is_ok() || cfg!(debug_assertions);
    for &(s, pspeed) in paper {
        let heads = model.heads_per_device(8) as u64;
        let w = AttnWorkload::decode(1, heads, s, model.head_dim as u64);
        let lat = |elem: u64| {
            spec.fastattn_latency(
                &w,
                &FastAttnOptions { elem_bytes: elem, ..Default::default() },
            )
            .latency_s
        };
        let fp16 = lat(2);
        let int8 = lat(1);
        let measured = if smoke && s > 512 {
            None
        } else {
            let (hd, hh) = (model.head_dim as usize, model.heads_per_device(8) as usize);
            let (f32_s, f32_out) = host_paged_decode(s as usize, hh, hd, PageCodec::F32, 2);
            let (i8_s, i8_out) = host_paged_decode(s as usize, hh, hd, PageCodec::Int8, 2);
            let err = f32_out
                .iter()
                .zip(&i8_out)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err < 0.05, "int8 host decode drifted at seq {s}: max err {err}");
            Some((f32_s, i8_s))
        };
        let (hf, hi, hx) = match measured {
            Some((f, i)) => (format!("{:.1}", f * 1e6), format!("{:.1}", i * 1e6), x(f / i)),
            None => ("—".into(), "—".into(), "—".into()),
        };
        t.row(&[
            format!("{s}"),
            format!("{:.2}", fp16 * 1e6),
            format!("{:.2}", int8 * 1e6),
            x(fp16 / int8),
            x(pspeed),
            hf,
            hi,
            hx,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table9_measured_host_columns_agree() {
        // the measured columns time real kernels: nonzero seconds, and
        // the int8 pass tracks f32 within quantization tolerance while
        // differing from it (proof it exercised the int8 path)
        let (f_s, f_out) = host_paged_decode(96, 4, 32, PageCodec::F32, 1);
        let (i_s, i_out) = host_paged_decode(96, 4, 32, PageCodec::Int8, 1);
        assert!(f_s > 0.0 && i_s > 0.0);
        let err = f_out
            .iter()
            .zip(&i_out)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 0.05, "int8 decode out of tolerance: {err}");
        assert!(err > 0.0, "int8 decode suspiciously identical to f32");
    }

    #[test]
    fn fig7_speedups_in_band() {
        // sanity: every PanGu-38B speedup within a generous paper band
        let spec = AscendSpec::default();
        let opts = FastAttnOptions::default();
        for s in [1024u64, 4096, 16384] {
            let w = pangu38_w(s);
            let sp = spec.standard_attention_latency(&w)
                / spec.fastattn_latency(&w, &opts).latency_s;
            assert!(sp > 2.5 && sp < 13.0, "S={s}: {sp:.2}");
        }
    }

    #[test]
    fn fig9_reductions_grow_with_seq() {
        let spec = AscendSpec::default();
        let red = |s: u64| {
            let w = pangu38_w(s);
            let l = |b1: u64| {
                spec.fastattn_latency(
                    &w,
                    &FastAttnOptions {
                        tiling: Tiling::TwoLevel { block1: b1, block2: 128.min(b1) },
                        ..Default::default()
                    },
                )
                .latency_s
            };
            1.0 - l(512) / l(128)
        };
        assert!(red(16384) >= red(4096) * 0.8, "reduction should not collapse");
        assert!(red(4096) > 0.10, "some reduction at 4K: {}", red(4096));
    }

    #[test]
    fn table6_speedup_large() {
        // paper: ~5.16× at B=1 — accept 2.5×..9×
        let spec = AscendSpec::default();
        let model = models::LLAMA2_7B;
        let lat = |fused: bool| {
            spec.layer_decode_latency(
                1, 32, 512, 128, model.hidden(), model.ffn as u64, 1, 2, fused, true,
            )
        };
        let sp = lat(false) / lat(true);
        assert!(sp > 2.0 && sp < 10.0, "{sp:.2}");
    }

    #[test]
    fn all_tables_render() {
        // smoke: all report builders terminate and have rows
        fig7_single_npu().print();
        fig9_blocksize_sweep().print();
        fig10_multi_npu().print();
        table2_ablation().print();
        table4_e2e().print();
        table6_throughput().print();
        table7_vit_breakdown().print();
        table8_deit().print();
        table9_quant().print();
    }
}
