//! Minimal property-testing support (the real `proptest` crate is not
//! available in this offline environment).
//!
//! Provides a fast deterministic RNG ([`Rng`], xoshiro256**) and a
//! [`check`] driver that runs a property over N seeded cases and reports
//! the failing seed — rerun with `Rng::new(seed)` to reproduce.

/// xoshiro256** — small, fast, good-quality deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded construction (splitmix64 expansion of the seed).
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = move || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`; `n > 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f32 in `[-1, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32 * 2.0 - 1.0
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli(1/2).
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick one element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// A vec of `n` uniform f32s in [-1, 1).
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32()).collect()
    }
}

/// Run `prop` over `cases` seeded RNGs; panics with the failing seed on
/// the first `Err`.
pub fn check<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Assert-like helper for property bodies.
#[macro_export]
macro_rules! prop_ensure {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Rng::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn f32_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((-1.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn check_reports_seed() {
        check(10, |rng| {
            prop_ensure!(rng.below(100) != 7 || rng.bool() || true, "x");
            if rng.f64() > -1.0 && rng.next_u64() % 3 == 0 {
                Err("forced".into())
            } else {
                Ok(())
            }
        });
    }
}
