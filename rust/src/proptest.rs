//! Minimal property-testing support (the real `proptest` crate is not
//! available in this offline environment).
//!
//! Provides a fast deterministic RNG ([`Rng`], xoshiro256**) and a
//! [`check`] driver that runs a property over N seeded cases and reports
//! the failing seed — rerun with `Rng::new(seed)` to reproduce.

/// xoshiro256** — small, fast, good-quality deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded construction (splitmix64 expansion of the seed).
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = move || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`; `n > 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f32 in `[-1, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32 * 2.0 - 1.0
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli(1/2).
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick one element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// A vec of `n` uniform f32s in [-1, 1).
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32()).collect()
    }
}

/// Largest absolute elementwise difference (∞-norm of `a - b`); the
/// agreement metric every cross-kernel property uses.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Expand GQA KV `[kv_heads, skv, d]` to MHA `[heads, skv, d]` by
/// repeating each KV head over its query-head group — the bridge every
/// GQA-vs-oracle test uses (`kv_heads` must divide `heads`).
pub fn expand_kv(src: &[f32], heads: usize, kv_heads: usize, skv: usize, d: usize) -> Vec<f32> {
    assert!(kv_heads >= 1 && heads % kv_heads == 0, "kv_heads must divide heads");
    assert_eq!(src.len(), kv_heads * skv * d, "src shape");
    let group = heads / kv_heads;
    let mut out = Vec::with_capacity(heads * skv * d);
    for head in 0..heads {
        let g = head / group;
        out.extend_from_slice(&src[g * skv * d..][..skv * d]);
    }
    out
}

/// Run `prop` over `cases` seeded RNGs; panics with the failing seed on
/// the first `Err`.
pub fn check<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Assert-like helper for property bodies.
#[macro_export]
macro_rules! prop_ensure {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Rng::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn f32_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((-1.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn check_reports_seed() {
        check(10, |rng| {
            prop_ensure!(rng.below(100) != 7 || rng.bool() || true, "x");
            if rng.f64() > -1.0 && rng.next_u64() % 3 == 0 {
                Err("forced".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn max_abs_diff_basics() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}

/// Cross-kernel agreement properties: `standard` is the numeric oracle,
/// `flash` must match it within FP tolerance for every shape/tiling
/// (including GQA), and `batch` must match `flash` **exactly** while
/// being invariant to the worker count.
#[cfg(test)]
mod attention_props {
    use super::{check, expand_kv, max_abs_diff, Rng};
    use crate::attention::batch::{
        batch_decode_attention, BatchShape, ParallelConfig, SeqAttn, SeqKv, WorkPool,
    };
    use crate::attention::flash::{flash_attention, FlashParams};
    use crate::attention::standard::{standard_attention, StdParams};
    use crate::coordinator::kv_cache::{
        BlockTable, CacheShape, PagePool, PcieLink, Tier, TieredPagePool,
    };
    use crate::prop_ensure;

    /// Pick a random (heads, kv_heads) pair with kv_heads | heads.
    fn gqa_pair(rng: &mut Rng) -> (usize, usize) {
        let h = *rng.pick(&[1usize, 2, 3, 4, 6, 8]);
        let divisors: Vec<usize> = (1..=h).filter(|k| h % k == 0).collect();
        let kvh = *rng.pick(&divisors);
        (h, kvh)
    }

    /// flash (GQA, any tiling, causal or not) == standard on expanded KV.
    #[test]
    fn prop_flash_gqa_equals_standard() {
        check(64, |rng| {
            let (h, kvh) = gqa_pair(rng);
            let sq = rng.range(1, 16);
            let skv = sq + rng.range(0, 24);
            let d = *rng.pick(&[1usize, 4, 8, 16]);
            let causal = rng.bool();
            let (bq, bkv) = (rng.range(1, 10), rng.range(1, 16));
            let q = rng.f32_vec(h * sq * d);
            let k = rng.f32_vec(kvh * skv * d);
            let v = rng.f32_vec(kvh * skv * d);
            let scale = 1.0 / (d as f32).sqrt();

            let mut flash = vec![0.0; h * sq * d];
            flash_attention(
                &q,
                &k,
                &v,
                &mut flash,
                &FlashParams {
                    heads: h,
                    kv_heads: kvh,
                    seq_q: sq,
                    seq_kv: skv,
                    head_dim: d,
                    causal,
                    block_q: bq,
                    block_kv: bkv,
                    scale,
                },
            );

            let (ke, ve) = (expand_kv(&k, h, kvh, skv, d), expand_kv(&v, h, kvh, skv, d));
            let mut std = vec![0.0; h * sq * d];
            standard_attention(
                &q,
                &ke,
                &ve,
                &mut std,
                &StdParams { heads: h, seq_q: sq, seq_kv: skv, head_dim: d, causal, scale },
            );
            let err = max_abs_diff(&flash, &std);
            prop_ensure!(
                err < 2e-5,
                "h={h} kvh={kvh} sq={sq} skv={skv} d={d} causal={causal} \
                 bq={bq} bkv={bkv}: err {err}"
            );
            Ok(())
        });
    }

    /// batch == per-sequence flash (bit-exact) == standard (tolerance),
    /// and threads=1 == threads=N bit-exact — over random decode batches.
    #[test]
    fn prop_batch_flash_standard_agree() {
        check(40, |rng| {
            let (h, kvh) = gqa_pair(rng);
            let d = *rng.pick(&[4usize, 8, 16]);
            let stride = rng.range(1, 40);
            let nseq = rng.range(1, 9);
            let block_kv = rng.range(1, 20);
            let threads = rng.range(2, 6);

            let mut qs = Vec::new();
            let mut ks = Vec::new();
            let mut vs = Vec::new();
            let mut lens = Vec::new();
            for _ in 0..nseq {
                qs.push(rng.f32_vec(h * d));
                ks.push(rng.f32_vec(kvh * stride * d));
                vs.push(rng.f32_vec(kvh * stride * d));
                lens.push(rng.range(0, stride + 1));
            }
            let seqs: Vec<SeqAttn<'_>> = (0..nseq)
                .map(|i| SeqAttn::contig(&qs[i], &ks[i], &vs[i], lens[i]))
                .collect();
            let mut shape = BatchShape::new(h, kvh, d, stride);
            shape.block_kv = block_kv;

            let n = nseq * h * d;
            let mut seq_out = vec![0.0; n];
            batch_decode_attention(
                &shape,
                &seqs,
                &mut seq_out,
                &WorkPool::new(ParallelConfig::sequential()),
            );
            let mut par_out = vec![0.0; n];
            batch_decode_attention(
                &shape,
                &seqs,
                &mut par_out,
                &WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 }),
            );
            prop_ensure!(
                seq_out == par_out,
                "threads=1 vs threads={threads} not bit-identical \
                 (h={h} kvh={kvh} d={d} nseq={nseq})"
            );

            // per-sequence flash on the compacted valid prefix
            for (i, s) in seqs.iter().enumerate() {
                let kv = s.kv_len;
                let mut k = Vec::with_capacity(kvh * kv * d);
                let mut v = Vec::with_capacity(kvh * kv * d);
                for g in 0..kvh {
                    k.extend_from_slice(&ks[i][g * stride * d..][..kv * d]);
                    v.extend_from_slice(&vs[i][g * stride * d..][..kv * d]);
                }
                let mut flash = vec![0.0; h * d];
                flash_attention(
                    s.q,
                    &k,
                    &v,
                    &mut flash,
                    &FlashParams {
                        heads: h,
                        kv_heads: kvh,
                        seq_q: 1,
                        seq_kv: kv,
                        head_dim: d,
                        causal: false,
                        block_q: 1,
                        block_kv,
                        scale: shape.scale,
                    },
                );
                prop_ensure!(
                    par_out[i * h * d..][..h * d] == flash[..],
                    "batch vs flash mismatch at seq {i} (h={h} kvh={kvh} kv={kv})"
                );

                if kv > 0 {
                    let (ke, ve) =
                        (expand_kv(&k, h, kvh, kv, d), expand_kv(&v, h, kvh, kv, d));
                    let mut std = vec![0.0; h * d];
                    standard_attention(
                        s.q,
                        &ke,
                        &ve,
                        &mut std,
                        &StdParams {
                            heads: h,
                            seq_q: 1,
                            seq_kv: kv,
                            head_dim: d,
                            causal: false,
                            scale: shape.scale,
                        },
                    );
                    let err = max_abs_diff(&flash, &std);
                    prop_ensure!(
                        err < 2e-5,
                        "batch vs standard err {err} at seq {i} (h={h} kvh={kvh} kv={kv})"
                    );
                }
            }
            Ok(())
        });
    }

    /// Paged KV (real `PagePool` + `BlockTable` glue) is bit-identical
    /// to contiguous planes over random page sizes, GQA shapes, KV
    /// lengths and thread counts.
    #[test]
    fn prop_paged_equals_contig() {
        check(40, |rng| {
            let (h, kvh) = gqa_pair(rng);
            let d = *rng.pick(&[4usize, 8, 16]);
            let stride = rng.range(1, 40);
            let nseq = rng.range(1, 7);
            let page_size = rng.range(1, 9);
            let threads = rng.range(1, 6);

            // single-layer cache geometry: attention sees one layer plane
            let cache = CacheShape { layers: 1, kv_heads: kvh, max_seq: stride, head_dim: d };
            let max_blocks = stride.div_ceil(page_size);
            let mut pool =
                PagePool::new(page_size, d, (nseq + 2) * kvh * max_blocks + 3);
            // churn the free list so tables get non-identity page maps
            let mut churn = BlockTable::new(cache, page_size);
            churn.ensure_capacity(stride.min(page_size * 2), &mut pool).unwrap();

            let mut qs = Vec::new();
            let mut ks = Vec::new();
            let mut vs = Vec::new();
            let mut lens = Vec::new();
            let mut tables = Vec::new();
            for i in 0..nseq {
                qs.push(rng.f32_vec(h * d));
                ks.push(rng.f32_vec(kvh * stride * d));
                vs.push(rng.f32_vec(kvh * stride * d));
                lens.push(rng.range(0, stride + 1));
                let mut t = BlockTable::new(cache, page_size);
                t.ensure_capacity(lens[i], &mut pool).unwrap();
                if i == 0 {
                    churn.release_all(&mut pool);
                }
                for g in 0..kvh {
                    for r in 0..lens[i] {
                        let (page, slot) = t.locate(0, g, r);
                        let src = g * stride * d + r * d;
                        pool.write_row(page, slot, &ks[i][src..src + d], &vs[i][src..src + d]);
                    }
                }
                tables.push(t);
            }

            let shape = BatchShape::new(h, kvh, d, stride);
            let n = nseq * h * d;
            let wp = WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 });

            let contig: Vec<SeqAttn<'_>> = (0..nseq)
                .map(|i| SeqAttn::contig(&qs[i], &ks[i], &vs[i], lens[i]))
                .collect();
            let mut out_c = vec![0.0; n];
            batch_decode_attention(&shape, &contig, &mut out_c, &wp);

            let paged: Vec<SeqAttn<'_>> = (0..nseq)
                .map(|i| SeqAttn {
                    q: &qs[i],
                    kv: SeqKv::Paged {
                        k_store: pool.k_store(),
                        v_store: pool.v_store(),
                        pages: tables[i].layer_pages(0),
                        max_blocks: tables[i].max_blocks(),
                        page_size,
                    },
                    kv_len: lens[i],
                })
                .collect();
            let mut out_p = vec![0.0; n];
            batch_decode_attention(&shape, &paged, &mut out_p, &wp);

            prop_ensure!(
                out_c == out_p,
                "paged != contig (h={h} kvh={kvh} d={d} stride={stride} \
                 page_size={page_size} threads={threads})"
            );
            Ok(())
        });
    }

    /// Tiered decode gather (blocks split across the device and host
    /// stores of a real `TieredPagePool`) is bit-identical to
    /// device-only/contiguous decode over random migration schedules,
    /// page sizes, GQA shapes and thread counts — including migrations
    /// interleaved with later KV writes (the mid-decode offload case)
    /// and writes landing on already-migrated blocks (a chunked prefill
    /// filling a cold tail).
    #[test]
    fn prop_tiered_gather_equals_device_only() {
        check(40, |rng| {
            let (h, kvh) = gqa_pair(rng);
            let d = *rng.pick(&[4usize, 8, 16]);
            let stride = rng.range(1, 40);
            let nseq = rng.range(1, 6);
            let page_size = rng.range(1, 9);
            let threads = rng.range(1, 6);

            // single-layer cache geometry: attention sees one layer plane
            let cache = CacheShape { layers: 1, kv_heads: kvh, max_seq: stride, head_dim: d };
            let max_blocks = stride.div_ceil(page_size);
            let cap = nseq * kvh * max_blocks + 2;
            let mut pools = TieredPagePool::new(page_size, d, cap, cap, PcieLink::default());

            let mut qs = Vec::new();
            let mut ks = Vec::new();
            let mut vs = Vec::new();
            let mut lens = Vec::new();
            let mut tables = Vec::new();
            for i in 0..nseq {
                qs.push(rng.f32_vec(h * d));
                ks.push(rng.f32_vec(kvh * stride * d));
                vs.push(rng.f32_vec(kvh * stride * d));
                lens.push(rng.range(0, stride + 1));
                let mut t = BlockTable::new(cache, page_size);

                // write a random prefix on-device…
                let split = rng.range(0, lens[i] + 1);
                let write = |t: &BlockTable, pools: &mut TieredPagePool, lo: usize, hi: usize| {
                    for g in 0..kvh {
                        for r in lo..hi {
                            let (tier, page, slot) = t.locate_tiered(0, g, r);
                            let src = g * stride * d + r * d;
                            pools.write_row(
                                tier,
                                page,
                                slot,
                                &ks[i][src..src + d],
                                &vs[i][src..src + d],
                            );
                        }
                    }
                };
                t.ensure_capacity(split, pools.device_mut()).unwrap();
                write(&t, &mut pools, 0, split);
                // …migrate a random subset of blocks…
                for b in 0..t.blocks() {
                    if rng.bool() {
                        t.migrate_block_to_host(b, &mut pools).unwrap();
                    }
                }
                // …then finish writing (rows may land in host-tier
                // blocks) and migrate a second random wave
                t.ensure_capacity(lens[i], pools.device_mut()).unwrap();
                write(&t, &mut pools, split, lens[i]);
                for b in 0..t.blocks() {
                    if t.block_tier(b) == Tier::Device && rng.bool() {
                        t.migrate_block_to_host(b, &mut pools).unwrap();
                    }
                }
                tables.push(t);
            }

            let shape = BatchShape::new(h, kvh, d, stride);
            let n = nseq * h * d;
            let wp = WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 });

            let contig: Vec<SeqAttn<'_>> = (0..nseq)
                .map(|i| SeqAttn::contig(&qs[i], &ks[i], &vs[i], lens[i]))
                .collect();
            let mut out_c = vec![0.0; n];
            batch_decode_attention(&shape, &contig, &mut out_c, &wp);

            let tiered: Vec<SeqAttn<'_>> = (0..nseq)
                .map(|i| SeqAttn {
                    q: &qs[i],
                    kv: SeqKv::Tiered {
                        k_device: pools.device().k_store(),
                        v_device: pools.device().v_store(),
                        k_host: pools.host().k_store(),
                        v_host: pools.host().v_store(),
                        pages: tables[i].layer_pages(0),
                        tiers: tables[i].layer_tiers(0),
                        max_blocks: tables[i].max_blocks(),
                        page_size,
                    },
                    kv_len: lens[i],
                })
                .collect();
            let mut out_t = vec![0.0; n];
            batch_decode_attention(&shape, &tiered, &mut out_t, &wp);

            prop_ensure!(
                out_c == out_t,
                "tiered != contig (h={h} kvh={kvh} d={d} stride={stride} \
                 page_size={page_size} threads={threads})"
            );

            // migration accounting coherence: bytes are pages × page
            // bytes, and every batch moved at least one page
            let st = pools.stats();
            prop_ensure!(
                st.bytes_moved == st.pages_moved * pools.page_bytes() as u64,
                "bytes {} != pages {} × page_bytes {}",
                st.bytes_moved,
                st.pages_moved,
                pools.page_bytes()
            );
            prop_ensure!(
                (st.batches == 0) == (st.pages_moved == 0),
                "batches {} vs pages {}",
                st.batches,
                st.pages_moved
            );
            Ok(())
        });
    }

    /// Swap-out/restore and promotion are pure placement: over random
    /// schedules of per-block migrations, whole-table suspends, full
    /// restores and single-block promotions — interleaved with KV
    /// writes, across page sizes, GQA shapes and thread counts — the
    /// tiered gather stays bit-identical to contiguous decode, and the
    /// two-direction transfer accounting stays coherent (bytes = pages
    /// × page_bytes per direction, batches iff pages, no page leaked
    /// across either tier).
    #[test]
    fn prop_suspend_resume_promote_gather_bit_identical() {
        check(40, |rng| {
            let (h, kvh) = gqa_pair(rng);
            let d = *rng.pick(&[4usize, 8]);
            let stride = rng.range(1, 33);
            let nseq = rng.range(1, 5);
            let page_size = rng.range(1, 9);
            let threads = rng.range(1, 5);

            let cache = CacheShape { layers: 1, kv_heads: kvh, max_seq: stride, head_dim: d };
            let max_blocks = stride.div_ceil(page_size);
            let cap = nseq * kvh * max_blocks + 2;
            let mut pools = TieredPagePool::new(page_size, d, cap, cap, PcieLink::default());

            let mut qs = Vec::new();
            let mut ks = Vec::new();
            let mut vs = Vec::new();
            let mut lens = Vec::new();
            let mut tables = Vec::new();
            for i in 0..nseq {
                qs.push(rng.f32_vec(h * d));
                ks.push(rng.f32_vec(kvh * stride * d));
                vs.push(rng.f32_vec(kvh * stride * d));
                lens.push(rng.range(0, stride + 1));
                let mut t = BlockTable::new(cache, page_size);

                // write a random prefix on-device…
                let split = rng.range(0, lens[i] + 1);
                let write = |t: &BlockTable, pools: &mut TieredPagePool, lo: usize, hi: usize| {
                    for g in 0..kvh {
                        for r in lo..hi {
                            let (tier, page, slot) = t.locate_tiered(0, g, r);
                            let src = g * stride * d + r * d;
                            pools.write_row(
                                tier,
                                page,
                                slot,
                                &ks[i][src..src + d],
                                &vs[i][src..src + d],
                            );
                        }
                    }
                };
                t.ensure_capacity(split, pools.device_mut()).unwrap();
                write(&t, &mut pools, 0, split);
                // …run a random placement schedule: single-block
                // migrations, a whole-table suspend (possibly restored
                // right away), single-block promotions…
                t.mark_gathered(i as u64 + 1);
                match rng.below(4) {
                    0 => {
                        for b in 0..t.blocks() {
                            if rng.bool() {
                                t.migrate_block_to_host(b, &mut pools).unwrap();
                            }
                        }
                    }
                    1 => {
                        t.suspend_to_host(&mut pools).unwrap();
                        prop_ensure!(
                            t.blocks() == 0 || t.device_blocks() == 0,
                            "suspend must park every device block"
                        );
                        if rng.bool() {
                            t.resume_from_host(&mut pools).unwrap();
                            prop_ensure!(t.host_blocks() == 0, "restore must be total");
                        }
                    }
                    2 => {
                        t.suspend_to_host(&mut pools).unwrap();
                        // promote a random subset back, hottest-first
                        // API: promotion order must not matter
                        while let Some((_, b)) = t.hottest_host_block() {
                            if rng.bool() {
                                t.promote_block_to_device(b, &mut pools).unwrap();
                            } else {
                                break;
                            }
                        }
                    }
                    _ => {}
                }
                // …then finish writing (rows may land in parked
                // blocks) and maybe suspend once more
                t.ensure_capacity(lens[i], pools.device_mut()).unwrap();
                write(&t, &mut pools, split, lens[i]);
                if rng.bool() {
                    t.suspend_to_host(&mut pools).unwrap();
                }
                tables.push(t);
            }

            let shape = BatchShape::new(h, kvh, d, stride);
            let n = nseq * h * d;
            let wp = WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 });

            let contig: Vec<SeqAttn<'_>> = (0..nseq)
                .map(|i| SeqAttn::contig(&qs[i], &ks[i], &vs[i], lens[i]))
                .collect();
            let mut out_c = vec![0.0; n];
            batch_decode_attention(&shape, &contig, &mut out_c, &wp);

            let tiered: Vec<SeqAttn<'_>> = (0..nseq)
                .map(|i| SeqAttn {
                    q: &qs[i],
                    kv: SeqKv::Tiered {
                        k_device: pools.device().k_store(),
                        v_device: pools.device().v_store(),
                        k_host: pools.host().k_store(),
                        v_host: pools.host().v_store(),
                        pages: tables[i].layer_pages(0),
                        tiers: tables[i].layer_tiers(0),
                        max_blocks: tables[i].max_blocks(),
                        page_size,
                    },
                    kv_len: lens[i],
                })
                .collect();
            let mut out_t = vec![0.0; n];
            batch_decode_attention(&shape, &tiered, &mut out_t, &wp);

            prop_ensure!(
                out_c == out_t,
                "suspend/restore/promote changed gather bits (h={h} kvh={kvh} d={d} \
                 stride={stride} page_size={page_size} threads={threads})"
            );

            // two-direction accounting coherence
            let st = pools.stats();
            prop_ensure!(
                st.bytes_moved == st.pages_moved * pools.page_bytes() as u64,
                "out bytes {} != pages {} × page_bytes",
                st.bytes_moved,
                st.pages_moved
            );
            prop_ensure!(
                st.promoted_bytes == st.pages_promoted * pools.page_bytes() as u64,
                "in bytes {} != pages {} × page_bytes",
                st.promoted_bytes,
                st.pages_promoted
            );
            prop_ensure!(
                (st.batches == 0) == (st.pages_moved == 0),
                "out batches {} vs pages {}",
                st.batches,
                st.pages_moved
            );
            prop_ensure!(
                (st.promotions == 0) == (st.pages_promoted == 0),
                "in batches {} vs pages {}",
                st.promotions,
                st.pages_promoted
            );
            prop_ensure!(
                st.pages_promoted <= st.pages_moved,
                "cannot promote pages that never migrated"
            );

            // full drain: no page leaked on either tier
            for mut t in tables {
                t.release_all_tiered(&mut pools);
            }
            prop_ensure!(
                pools.free_pages_total() == pools.total_pages(),
                "page leak: {} free of {}",
                pools.free_pages_total(),
                pools.total_pages()
            );
            Ok(())
        });
    }

    /// Shared-prefix gather (an adopter's table pointing at the owner's
    /// pages through a real `PrefixIndex`, split by copy-on-write at
    /// the divergence point) is bit-identical to fully unshared tables
    /// over random prefix lengths, page sizes, GQA shapes and thread
    /// counts — and neither the COW split nor the adopter's divergent
    /// writes ever mutate the owner's pages.
    #[test]
    fn prop_shared_prefix_gather_equals_unshared() {
        use crate::coordinator::kv_cache::PrefixIndex;
        check(40, |rng| {
            let (h, kvh) = gqa_pair(rng);
            let d = *rng.pick(&[4usize, 8, 16]);
            let stride = rng.range(2, 40);
            let page_size = rng.range(1, 9);
            let threads = rng.range(1, 6);

            // single-layer cache geometry: attention sees one layer plane
            let cache = CacheShape { layers: 1, kv_heads: kvh, max_seq: stride, head_dim: d };
            let max_blocks = stride.div_ceil(page_size);
            let mut pool = PagePool::new(page_size, d, 4 * kvh * max_blocks + 4);
            let mut index = PrefixIndex::new(cache, page_size, 64);

            // owner sequence: prompt of la tokens, KV rows 0..la
            let la = rng.range(1, stride + 1);
            let owner_prompt: Vec<i32> = (0..la).map(|_| rng.below(50) as i32).collect();
            let ks_a = rng.f32_vec(kvh * stride * d);
            let vs_a = rng.f32_vec(kvh * stride * d);
            let mut ta = BlockTable::new(cache, page_size);
            ta.ensure_capacity(la, &mut pool).unwrap();
            #[allow(clippy::too_many_arguments)]
            let write = |t: &BlockTable,
                         pool: &mut PagePool,
                         ks: &[f32],
                         vs: &[f32],
                         lo: usize,
                         hi: usize| {
                    for g in 0..kvh {
                        for r in lo..hi {
                            let (page, slot) = t.locate(0, g, r);
                            let src = g * stride * d + r * d;
                            pool.write_row(page, slot, &ks[src..src + d], &vs[src..src + d]);
                        }
                    }
                };
            write(&ta, &mut pool, &ks_a, &vs_a, 0, la);
            index.register(&owner_prompt, &ta, &mut pool);

            // adopter: shares a random common prompt prefix, then
            // diverges.  Same prefix ⇒ same KV rows, so its reference
            // rows copy the owner's over the common range.
            let lb = rng.range(1, stride + 1);
            let common = rng.range(0, la.min(lb) + 1);
            let mut adopter_prompt: Vec<i32> = owner_prompt[..common].to_vec();
            while adopter_prompt.len() < lb {
                adopter_prompt.push(50 + rng.below(50) as i32); // disjoint id space
            }
            let mut ks_b = rng.f32_vec(kvh * stride * d);
            let mut vs_b = rng.f32_vec(kvh * stride * d);
            for g in 0..kvh {
                let at = g * stride * d;
                ks_b[at..at + common * d].copy_from_slice(&ks_a[at..at + common * d]);
                vs_b[at..at + common * d].copy_from_slice(&vs_a[at..at + common * d]);
            }
            let mut tb = BlockTable::new(cache, page_size);
            let adopted = index.adopt(&adopter_prompt, &mut tb, &mut pool);
            prop_ensure!(
                adopted < lb.max(1),
                "adopted {adopted} of a {lb}-token prompt (common {common})"
            );

            // snapshot the owner's physical rows before the adopter
            // diverges
            let snap = |t: &BlockTable, pool: &PagePool, len: usize| -> Vec<f32> {
                let mut out = Vec::new();
                for g in 0..kvh {
                    for r in 0..len {
                        let (page, slot) = t.locate(0, g, r);
                        let at = (page as usize * page_size + slot) * d;
                        out.extend_from_slice(&pool.k_store()[at..at + d]);
                        out.extend_from_slice(&pool.v_store()[at..at + d]);
                    }
                }
                out
            };
            let owner_before = snap(&ta, &pool, la);

            // grow, split whatever the divergent writes overlap, write
            tb.ensure_capacity(lb, &mut pool).unwrap();
            tb.cow_unshare(adopted, lb, &mut pool).unwrap();
            write(&tb, &mut pool, &ks_b, &vs_b, adopted, lb);

            prop_ensure!(
                owner_before == snap(&ta, &pool, la),
                "COW split / divergent writes mutated the owner's pages \
                 (la={la} lb={lb} common={common} page_size={page_size})"
            );

            // shared pair vs fully unshared pair: bit-identical attention
            let qa = rng.f32_vec(h * d);
            let qb = rng.f32_vec(h * d);
            let shape = BatchShape::new(h, kvh, d, stride);
            let wp = WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 });
            fn paged_seq<'a>(
                pool: &'a PagePool,
                t: &'a BlockTable,
                q: &'a [f32],
                page_size: usize,
                len: usize,
            ) -> SeqAttn<'a> {
                SeqAttn {
                    q,
                    kv: SeqKv::Paged {
                        k_store: pool.k_store(),
                        v_store: pool.v_store(),
                        pages: t.layer_pages(0),
                        max_blocks: t.max_blocks(),
                        page_size,
                    },
                    kv_len: len,
                }
            }
            let mut out_shared = vec![0.0; 2 * h * d];
            batch_decode_attention(
                &shape,
                &[
                    paged_seq(&pool, &ta, &qa, page_size, la),
                    paged_seq(&pool, &tb, &qb, page_size, lb),
                ],
                &mut out_shared,
                &wp,
            );
            let unshared = [
                SeqAttn::contig(&qa, &ks_a, &vs_a, la),
                SeqAttn::contig(&qb, &ks_b, &vs_b, lb),
            ];
            let mut out_unshared = vec![0.0; 2 * h * d];
            batch_decode_attention(&shape, &unshared, &mut out_unshared, &wp);
            prop_ensure!(
                out_shared == out_unshared,
                "shared != unshared (h={h} kvh={kvh} d={d} la={la} lb={lb} \
                 common={common} page_size={page_size} threads={threads})"
            );

            // exact free-list accounting: every holder released ⇒ empty
            ta.release_all(&mut pool);
            tb.release_all(&mut pool);
            index.clear(&mut pool);
            prop_ensure!(
                pool.used_pages() == 0,
                "leaked {} pages after full release",
                pool.used_pages()
            );
            Ok(())
        });
    }

    /// Interleaved grow/register/adopt/COW/release/evict schedules over
    /// one pool never leak or double-free: ref-count invariants hold
    /// throughout and the free list is exactly full once every holder
    /// lets go.  (Double-frees panic inside `PagePool::release`, so
    /// surviving the schedule is itself the assertion.)
    #[test]
    fn prop_share_cow_release_schedules_never_leak() {
        use crate::coordinator::kv_cache::PrefixIndex;
        check(60, |rng| {
            let kvh = rng.range(1, 4);
            let layers = rng.range(1, 3);
            let d = 4;
            let max_seq = rng.range(4, 25);
            let page_size = rng.range(1, 6);
            let cache = CacheShape { layers, kv_heads: kvh, max_seq, head_dim: d };
            let max_blocks = max_seq.div_ceil(page_size);
            let total = 6 * layers * kvh * max_blocks + 8;
            let mut pool = PagePool::new(page_size, d, total);
            let mut index = PrefixIndex::new(cache, page_size, rng.range(1, 8));

            // live tables with the prompt backing them
            let mut live: Vec<(BlockTable, Vec<i32>)> = Vec::new();
            for _ in 0..rng.range(8, 28) {
                match rng.below(6) {
                    // admit: new table; adopt if a prefix matches, then
                    // grow + COW to the full prompt
                    0 | 1 => {
                        if live.len() >= 4 {
                            continue;
                        }
                        let len = rng.range(1, max_seq + 1);
                        let prompt: Vec<i32> = if rng.bool() && !live.is_empty() {
                            // reuse a live prompt's prefix to provoke hits
                            let src = &live[rng.range(0, live.len())].1;
                            let take = rng.range(0, src.len() + 1).min(len);
                            let mut p = src[..take].to_vec();
                            while p.len() < len {
                                p.push(rng.below(30) as i32);
                            }
                            p
                        } else {
                            (0..len).map(|_| rng.below(30) as i32).collect()
                        };
                        let mut t = BlockTable::new(cache, page_size);
                        let adopted = index.adopt(&prompt, &mut t, &mut pool);
                        if t.ensure_capacity(len, &mut pool).is_err() {
                            t.release_all(&mut pool);
                            continue;
                        }
                        if t.cow_unshare(adopted, len, &mut pool).is_err() {
                            t.release_all(&mut pool);
                            continue;
                        }
                        live.push((t, prompt));
                    }
                    // register a live table's prompt
                    2 => {
                        if let Some((t, p)) =
                            (!live.is_empty()).then(|| &live[rng.range(0, live.len())])
                        {
                            index.register(p, t, &mut pool);
                        }
                    }
                    // finish: release a random table
                    3 => {
                        if !live.is_empty() {
                            let (mut t, _) = live.swap_remove(rng.range(0, live.len()));
                            t.release_all(&mut pool);
                        }
                    }
                    // COW a random row range of a random table
                    4 => {
                        if !live.is_empty() {
                            let i = rng.range(0, live.len());
                            let len = live[i].1.len();
                            let lo = rng.range(0, len);
                            let _ = live[i].0.cow_unshare(lo, len, &mut pool);
                        }
                    }
                    // reclaim: evict an idle run
                    _ => {
                        index.evict_idle(&mut pool);
                    }
                }
                // bounds that hold at every step: the pool can't track
                // more pages than exist, and everything live tables +
                // index reference is accounted as used
                let table_pages: std::collections::HashSet<u32> = live
                    .iter()
                    .flat_map(|(t, _)| (0..t.blocks()).flat_map(|b| t.block_group(b)))
                    .collect();
                let (used, d_t, d_i) =
                    (pool.used_pages(), table_pages.len(), index.pages_held());
                prop_ensure!(
                    used >= d_t && used >= d_i && used <= d_t + d_i,
                    "accounting out of bounds: used={used} tables={d_t} index={d_i}"
                );
            }
            for (mut t, _) in live {
                t.release_all(&mut pool);
            }
            index.clear(&mut pool);
            prop_ensure!(
                pool.used_pages() == 0,
                "leaked {} pages after draining the schedule",
                pool.used_pages()
            );
            prop_ensure!(pool.free_pages() == pool.num_pages(), "free list incomplete");
            Ok(())
        });
    }
}
