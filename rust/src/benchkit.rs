//! Minimal benchmarking harness (criterion is not available offline).
//!
//! Used by every `rust/benches/*.rs` target: warmup, timed iterations,
//! robust statistics, the paper-vs-measured table printer that the
//! table/figure reproduction benches share, and machine-readable JSON
//! emission (`BENCH_<name>.json`) so the perf trajectory is comparable
//! across PRs without scraping stdout.

use std::path::Path;
use std::time::Instant;

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
}

impl Stats {
    /// Events/second at the mean iteration time — the tok/s column of
    /// the decode-throughput benches (0.0 when nothing was measured).
    pub fn events_per_s(&self, events: f64) -> f64 {
        if self.mean_s <= 0.0 {
            return 0.0;
        }
        events / self.mean_s
    }
}

/// Run `f` with `warmup` unmeasured and `iters` measured iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    Stats {
        iters: n,
        mean_s: samples.iter().sum::<f64>() / n as f64,
        min_s: samples[0],
        p50_s: samples[n / 2],
        p99_s: samples[(n * 99 / 100).min(n - 1)],
    }
}

/// Pretty-print seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// A paper-vs-measured table printer shared by the reproduction benches.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells.to_vec());
    }

    /// Render to stdout with aligned columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{:>w$}  ", c, w = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        let total = widths.iter().sum::<usize>() + 2 * widths.len();
        println!("{}", "-".repeat(total));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format a speedup as `N.NN×`.
pub fn x(v: f64) -> String {
    format!("{v:.2}×")
}

/// Format milliseconds.
pub fn ms(v_s: f64) -> String {
    format!("{:.3}", v_s * 1e3)
}

/// Format an event rate (`events` per `seconds`) with an adaptive unit,
/// e.g. `"12.3 ktok/s"` — the decode-throughput column of the parallel
/// attention benches.
pub fn rate(events: f64, seconds: f64, unit: &str) -> String {
    if seconds <= 0.0 {
        return format!("∞ {unit}/s");
    }
    let r = events / seconds;
    if r >= 1e9 {
        format!("{:.2} G{unit}/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2} M{unit}/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2} k{unit}/s", r / 1e3)
    } else {
        format!("{r:.1} {unit}/s")
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a bench report as a JSON object:
/// `{"bench": .., "unit": .., "rows": [{"config": .., "value": ..}, ..]}`.
/// Non-finite values serialize as `null`.
pub fn bench_json(bench: &str, unit: &str, rows: &[(String, f64)]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{{\"bench\":\"{}\",\"unit\":\"{}\",\"rows\":[",
        json_escape(bench),
        json_escape(unit)
    ));
    for (i, (config, value)) in rows.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        if value.is_finite() {
            s.push_str(&format!(
                "{{\"config\":\"{}\",\"value\":{value}}}",
                json_escape(config)
            ));
        } else {
            s.push_str(&format!(
                "{{\"config\":\"{}\",\"value\":null}}",
                json_escape(config)
            ));
        }
    }
    s.push_str("]}\n");
    s
}

/// Write `bench_json` to `path` (conventionally `BENCH_<name>.json` in
/// the invocation directory) so each PR's numbers are machine-diffable.
pub fn write_bench_json(
    path: &Path,
    bench: &str,
    unit: &str,
    rows: &[(String, f64)],
) -> std::io::Result<()> {
    std::fs::write(path, bench_json(bench, unit, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0u64;
        let s = bench(2, 10, || n += 1);
        assert_eq!(s.iters, 10);
        assert_eq!(n, 12);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.p99_s);
    }

    #[test]
    fn events_per_s_inverts_mean() {
        let s = Stats { iters: 1, mean_s: 0.5, min_s: 0.5, p50_s: 0.5, p99_s: 0.5 };
        assert!((s.events_per_s(8.0) - 16.0).abs() < 1e-12);
        let z = Stats { iters: 0, mean_s: 0.0, min_s: 0.0, p50_s: 0.0, p99_s: 0.0 };
        assert_eq!(z.events_per_s(8.0), 0.0);
    }

    #[test]
    fn rate_units() {
        assert_eq!(rate(500.0, 1.0, "tok"), "500.0 tok/s");
        assert_eq!(rate(12_300.0, 1.0, "tok"), "12.30 ktok/s");
        assert_eq!(rate(2.5e6, 1.0, "B"), "2.50 MB/s");
        assert_eq!(rate(3.0e9, 1.0, "flop"), "3.00 Gflop/s");
        assert!(rate(1.0, 0.0, "tok").contains('∞'));
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-10).ends_with(" ns"));
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // should not panic
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn bench_json_parses_back() {
        let rows = vec![
            ("seq threads=1".to_string(), 1234.5),
            ("par \"q\"\\x".to_string(), f64::NAN),
        ];
        let s = bench_json("decode", "tok/s", &rows);
        let j = crate::runtime::json::Json::parse(&s).expect("emitted JSON parses");
        assert_eq!(j.req_str("bench").unwrap(), "decode");
        assert_eq!(j.req_str("unit").unwrap(), "tok/s");
        let arr = j.req_arr("rows").unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].req_str("config").unwrap(), "seq threads=1");
        assert!((arr[0].get("value").unwrap().num().unwrap() - 1234.5).abs() < 1e-9);
        assert_eq!(arr[1].req_str("config").unwrap(), "par \"q\"\\x");
        assert!(arr[1].get("value").unwrap().num().is_none(), "NaN → null");
    }

    #[test]
    fn write_bench_json_roundtrip() {
        let path = std::env::temp_dir().join("fastattn_bench_json_test.json");
        write_bench_json(&path, "b", "u", &[("c".into(), 2.0)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\":\"b\""));
        let _ = std::fs::remove_file(&path);
    }
}
