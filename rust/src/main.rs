//! `repro` — the FastAttention reproduction CLI (leader entrypoint).
//!
//! Subcommands (clap is unavailable offline; plain arg parsing):
//!
//!   repro serve  [--artifacts DIR] [--requests N] [--gen M]
//!       Start the serving engine over the AOT artifacts and run a
//!       synthetic batched workload; prints per-request latency and
//!       engine throughput.
//!
//!   repro table <id>|all
//!       Regenerate a paper table/figure (fig7, fig8, ..., table9).
//!
//!   repro simulate --model NAME --seq S [--devices N]
//!       One-shot Ascend/Volta operator latencies for a model shape.
//!
//!   repro plan-offload --model NAME --seq S [--gpus N]
//!       The §4.4 memory plan (eq. 15–20): L_GPU/L_CPU split.

use std::process::ExitCode;

use fastattn::benchkit::ms;
use fastattn::coordinator::{EngineConfig, GenParams, Server};
use fastattn::models;
use fastattn::reports;
use fastattn::sim::ascend::{AscendSpec, FastAttnOptions};
use fastattn::sim::memory::Deployment;
use fastattn::sim::volta::{VoltaKernel, VoltaSpec};
use fastattn::sim::AttnWorkload;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    let result = match cmd {
        "serve" => serve(rest),
        "table" => table(rest),
        "simulate" => simulate(rest),
        "plan-offload" => plan_offload(rest),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{HELP}");
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

const HELP: &str = "\
repro — FastAttention reproduction CLI

USAGE:
  repro serve [--artifacts DIR] [--requests N] [--gen M]
  repro table <fig7|fig8|fig9|fig10|fig11|fig16|fig17|table2..table9|all>
  repro simulate --model NAME --seq S [--devices N]
  repro plan-offload --model NAME --seq S [--gpus N]
";

fn serve(args: &[String]) -> anyhow::Result<()> {
    let dir = flag(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let n: usize = flag(args, "--requests").map(|v| v.parse()).transpose()?.unwrap_or(8);
    let gen: usize = flag(args, "--gen").map(|v| v.parse()).transpose()?.unwrap_or(8);

    println!("loading artifacts from {dir} …");
    let server = Server::start(dir, EngineConfig::default())?;
    println!("engine up; submitting {n} requests (gen {gen} tokens each)");

    let t0 = std::time::Instant::now();
    let waits: Vec<_> = (0..n)
        .map(|i| {
            let len = 3 + (i * 7) % 24;
            let prompt: Vec<i32> = (0..len).map(|j| ((i * 31 + j * 13) % 500 + 1) as i32).collect();
            server.submit(prompt, GenParams { max_new_tokens: gen, ..GenParams::default() })
        })
        .collect::<Result<_, _>>()?;
    for stream in waits {
        let id = stream.id();
        let resp = stream.wait()?;
        println!(
            "req {id}: prompt {} + {} tokens — ttft {} total {} ({:.1} tok/s decode)",
            resp.prompt_len,
            resp.tokens.len(),
            ms(resp.ttft_s),
            ms(resp.total_s),
            resp.decode_tps()
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.metrics()?;
    println!(
        "\ndone in {wall:.2}s — {} completed | prefill {} steps ({:.0} tok/s) | decode {} steps ({:.1} tok/s, mean batch {:.2})",
        m.completed,
        m.prefill_steps,
        m.prefill_tps(),
        m.decode_steps,
        m.decode_tps(),
        m.mean_decode_batch(),
    );
    Ok(())
}

fn table(args: &[String]) -> anyhow::Result<()> {
    let id = args.first().map(String::as_str).unwrap_or("all");
    if id == "all" {
        for id in reports::ALL {
            reports::by_id(id).unwrap().print();
        }
        return Ok(());
    }
    match reports::by_id(id) {
        Some(t) => {
            t.print();
            Ok(())
        }
        None => anyhow::bail!("unknown table id '{id}' (try: {})", reports::ALL.join(", ")),
    }
}

fn simulate(args: &[String]) -> anyhow::Result<()> {
    let name = flag(args, "--model").unwrap_or_else(|| "PanGu-38B".into());
    let seq: u64 = flag(args, "--seq").map(|v| v.parse()).transpose()?.unwrap_or(4096);
    let devices: u32 = flag(args, "--devices").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let model = models::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;

    let heads = model.heads_per_device(devices) as u64;
    let w = AttnWorkload::prefill(1, heads, seq, model.head_dim as u64, true);

    let ascend = AscendSpec::default();
    let std = ascend.standard_attention_latency(&w);
    let fast = ascend.fastattn_latency(&w, &FastAttnOptions::default());
    println!("== {} @ S={seq}, {} heads/device ({} devices) ==", model.name, heads, devices);
    println!("Ascend 910B:");
    println!("  standard attention : {}", ms(std));
    println!(
        "  FastAttention      : {}  ({:.2}× speedup, cube eff {:.1}%, {} syncs)",
        ms(fast.latency_s),
        std / fast.latency_s,
        fast.efficiency * 100.0,
        fast.pipeline.syncs
    );

    let volta = VoltaSpec::default();
    let xf = volta.attention_latency(VoltaKernel::Xformers, &w);
    let fa = volta.attention_latency(VoltaKernel::FastAttention, &w);
    println!("Tesla V100:");
    println!(
        "  xformers           : {}  ({:.1} TFLOPs/s)",
        ms(xf),
        volta.attention_tflops(VoltaKernel::Xformers, &w)
    );
    println!(
        "  FastAttention      : {}  ({:.1} TFLOPs/s, {:.2}×)",
        ms(fa),
        volta.attention_tflops(VoltaKernel::FastAttention, &w),
        xf / fa
    );
    Ok(())
}

fn plan_offload(args: &[String]) -> anyhow::Result<()> {
    let name = flag(args, "--model").unwrap_or_else(|| "PanGu-38B".into());
    let seq: u64 = flag(args, "--seq").map(|v| v.parse()).transpose()?.unwrap_or(65536);
    let gpus: u32 = flag(args, "--gpus").map(|v| v.parse()).transpose()?.unwrap_or(8);
    let model = models::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
    let dep = Deployment { n_gpus: gpus, ..Deployment::v100_node(model, seq, 50) };
    let plan = dep.plan();
    let gb = |b: u64| b as f64 / (1u64 << 30) as f64;
    println!("== CPU–GPU cooperative plan: {} @ S={seq}, {gpus}× V100-16GB ==", model.name);
    println!("  weights/GPU  : {:>8.2} GiB", gb(plan.weights_per_gpu));
    println!("  vocab        : {:>8.2} GiB", gb(plan.vocab));
    println!("  KV/layer/GPU : {:>8.2} MiB", plan.kv_per_layer_per_gpu as f64 / (1 << 20) as f64);
    println!("  M_mid        : {:>8.2} MiB", plan.mid_per_gpu as f64 / (1 << 20) as f64);
    println!(
        "  L_GPU = {}  L_CPU = {}  (of {} layers){}",
        plan.l_gpu,
        plan.l_cpu,
        model.layers,
        if plan.fits_without_offload { " — fits without offload" } else { "" }
    );
    println!(
        "  max seq without offload : {}K",
        dep.max_seq_without_offload() / 1024
    );
    println!(
        "  max seq with offload    : {}K (768 GiB host)",
        dep.max_seq_with_offload(768 << 30) / 1024
    );
    Ok(())
}
