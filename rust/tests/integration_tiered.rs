//! Integration: the two-tier paged KV cache (cold-page host offload).
//!
//! Runs the full engine stack over [`HostModelBackend`] (no artifacts
//! needed) with the device page pool forced small: cold pages migrate
//! to the host tier mid-decode over the modeled PCIe link, decode
//! gathers across both tiers, outputs stay bit-identical to the
//! unconstrained run, and the migration/preemption interplay always
//! terminates with every request served.
//!
//! tiny_gqa geometry used throughout: layers 2 × kv_heads 2 → a block
//! group is 4 pages; at page_size 16 / head_dim 8 one page is
//! 2·4·16·8 = 1 KiB, so one block group is 4 KiB.

use fastattn::attention::batch::ParallelConfig;
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout,
};

const GROUP_BYTES: usize = 4 * 1024;

fn tiered_engine(device_groups: usize, host_groups: usize, threads: usize) -> Engine {
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        device_kv_budget: device_groups * GROUP_BYTES,
        host_kv_budget: host_groups * GROUP_BYTES,
        page_size: 16,
        ..EngineConfig::default()
    };
    Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    )
}

/// The unconstrained reference: a device pool big enough that nothing
/// ever migrates or preempts.
fn unconstrained_engine(threads: usize) -> Engine {
    tiered_engine(1024, 0, threads)
}

fn run(e: &mut Engine, prompts: &[Vec<i32>], p: GenParams) -> Vec<Vec<i32>> {
    for pr in prompts {
        e.submit(pr.clone(), p).unwrap();
    }
    let mut out = e.run_until_idle().unwrap();
    out.sort_by_key(|r| r.id);
    out.into_iter().map(|r| r.tokens).collect()
}

/// A long prompt chunk-prefills into a device tier that cannot hold it;
/// cold pages migrate mid-flight and the output matches the
/// unconstrained run bit for bit.
#[test]
fn long_prompt_migrates_mid_decode_and_matches_unconstrained() {
    // 60 prompt + 20 generated = 80 tokens = 5 blocks; the device tier
    // holds 3 block groups, so at least 2 groups must offload.
    let prompt: Vec<i32> = (0..60).map(|i| (i * 3 + 1) % 64).collect();
    let p = GenParams { max_new_tokens: 20, eos_token: None, share_prefix: false };

    let mut base = unconstrained_engine(1);
    let want = run(&mut base, &[prompt.clone()], p);
    assert_eq!(base.metrics.pages_migrated, 0);
    assert_eq!(base.metrics.preemptions, 0);

    let mut tiered = tiered_engine(3, 8, 1);
    let got = run(&mut tiered, &[prompt], p);
    assert_eq!(got, want, "cold-page offload must not change greedy tokens");

    let m = &tiered.metrics;
    assert!(
        m.pages_migrated >= 2 * 4,
        "5 blocks over a 3-group device tier must migrate ≥ 2 groups, migrated {}",
        m.pages_migrated
    );
    assert_eq!(m.preemptions, 0, "a solo sequence is never preempted, only offloaded");
    assert!(m.migrations >= 2, "block groups move as separate batched transfers");
    assert_eq!(m.migrated_bytes, m.pages_migrated * 1024);
    assert!(m.pcie_modeled_s > 0.0, "migrations must charge the modeled link");
    // both tiers fully drained at idle
    assert_eq!(m.pages_used, 0);
    assert_eq!(m.host_pages_used, 0);
    assert_eq!(m.host_pages_total, 8 * 4);
    assert!(m.host_page_occupancy() == 0.0 && m.page_occupancy() == 0.0);
}

/// Two sequences contend for a tiny device tier backed by a small host
/// tier: the run needs *both* migration and preemption, never
/// livelocks, and every request's tokens match its solo unconstrained
/// run.
#[test]
fn migration_preemption_interplay_terminates_with_identical_tokens() {
    // each request: 8 prompt + 40 generated = 48 tokens = 3 groups;
    // device holds 2 groups, host 2 groups → the pair cannot coexist,
    // so the youngest is preempted and replayed after the oldest
    // finishes via its own cold-block offloads.
    let p = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
    let prompts: Vec<Vec<i32>> = vec![vec![1; 8], vec![2; 8]];

    let mut e = tiered_engine(2, 2, 1);
    let got = run(&mut e, &prompts, p);
    assert_eq!(got.len(), 2, "both requests complete despite the squeeze");
    assert!(got.iter().all(|t| t.len() == 40));
    let m = &e.metrics;
    assert!(m.pages_migrated >= 4, "the oldest sequence's third block needs an offload");
    assert!(m.preemptions >= 1, "the youngest must have been evicted at least once");
    assert!(m.alloc_failures >= 1);
    assert_eq!(m.pages_used, 0, "device tier drained at idle");
    assert_eq!(m.host_pages_used, 0, "host tier drained at idle");

    // preemption + replay + offload must not change any request's tokens
    for (pr, got) in prompts.iter().zip(&got) {
        let mut solo = unconstrained_engine(1);
        let want = run(&mut solo, &[pr.clone()], p);
        assert_eq!(&want[0], got, "prompt {pr:?}");
    }
}

/// Thread count must not change tokens when decode gathers across
/// tiers (the tiered generalization of the threads-invariance law).
#[test]
fn tiered_decode_is_thread_invariant() {
    let prompts: Vec<Vec<i32>> = (0..5)
        .map(|i| (0..(i * 7 + 3) % 24 + 1).map(|t| ((t * 5 + i) % 64) as i32).collect())
        .collect();
    let p = GenParams { max_new_tokens: 12, eos_token: None, share_prefix: false };
    let mut one = tiered_engine(2, 6, 1);
    let mut four = tiered_engine(2, 6, 4);
    let a = run(&mut one, &prompts, p);
    let b = run(&mut four, &prompts, p);
    assert_eq!(a, b, "threads must not change tiered decode tokens");
    assert_eq!(a, {
        let mut base = unconstrained_engine(4);
        run(&mut base, &prompts, p)
    });
}

/// A mixed workload under sustained pressure: many requests against a
/// small device tier, all served, host tier fully recycled between
/// sequence completions (no host-page leak across the run).
#[test]
fn sustained_pressure_recycles_host_pages() {
    let prompts: Vec<Vec<i32>> = (0..8)
        .map(|i| (0..(i * 5 + 2) % 30 + 1).map(|t| ((t * 7 + i) % 64) as i32).collect())
        .collect();
    let p = GenParams { max_new_tokens: 10, eos_token: None, share_prefix: false };
    let mut e = tiered_engine(2, 4, 1);
    let got = run(&mut e, &prompts, p);
    assert_eq!(got.len(), 8);
    assert!(got.iter().all(|t| t.len() == 10));
    assert_eq!(e.metrics.pages_used, 0);
    assert_eq!(e.metrics.host_pages_used, 0);
    assert_eq!(e.metrics.completed, 8);

    let mut base = unconstrained_engine(1);
    let want = run(&mut base, &prompts, p);
    assert_eq!(got, want, "pressure must not change any request's tokens");
}

/// Requests that exceed even the combined tiers are refused up front;
/// ones that need both tiers are admitted and complete.
#[test]
fn admission_counts_usable_pages_across_tiers() {
    let mut e = tiered_engine(2, 2, 1);
    // 4 groups usable = 64 token rows; 8 + 72 = 80 tokens won't ever fit
    assert!(e
        .submit(vec![1; 8], GenParams { max_new_tokens: 72, eos_token: None, share_prefix: false })
        .is_err());
    // 8 + 40 = 48 tokens = 3 groups > device alone (2), ≤ tiers (4): ok
    let id = e
        .submit(vec![1; 8], GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false })
        .unwrap();
    let out = e.run_until_idle().unwrap();
    assert_eq!(out[0].id, id);
    assert_eq!(out[0].tokens.len(), 40);
    assert!(e.metrics.pages_migrated > 0, "the third block lived on the host tier");
}
