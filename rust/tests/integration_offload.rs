//! Offload + collective integration: the §4.2/§4.4 subsystems composed.

use std::time::Duration;

use fastattn::coordinator::allreduce::{
    serial_all_reduce, tiled_all_reduce, BlockCompute,
};
use fastattn::coordinator::offload::{plan, step_latency, LayerPlacement};
use fastattn::models::{LLAMA2_70B, PANGU_38B, PANGU_71B};
use fastattn::sim::memory::Deployment;
use fastattn::sim::volta::VoltaSpec;

#[test]
fn offload_plan_consistent_across_models() {
    for model in [PANGU_38B, LLAMA2_70B, PANGU_71B] {
        let mut dep = Deployment::v100_node(model, 128 * 1024, 50);
        // bigger models need the 32 GB V100 variant
        if 2 * model.params / 8 > dep.gpu_mem_bytes {
            dep.gpu_mem_bytes = 32 << 30;
        }
        let p = plan(&dep);
        assert_eq!(p.placements.len(), model.layers as usize, "{}", model.name);
        assert_eq!(p.l_cpu + p.l_gpu, model.layers, "{}", model.name);
        // host layers are a strict prefix
        let mut seen_device = false;
        for pl in &p.placements {
            match pl {
                LayerPlacement::DeviceCompute => seen_device = true,
                LayerPlacement::HostCompute => {
                    assert!(!seen_device, "{}: non-prefix host layer", model.name)
                }
            }
        }
    }
}

#[test]
fn cooperative_never_slower_than_classical() {
    let spec = VoltaSpec::default();
    for s in [16u64, 32, 64, 128, 256] {
        let dep = Deployment::v100_node(PANGU_38B, s * 1024, 50);
        let p = plan(&dep);
        let st = step_latency(&spec, &dep, &p);
        assert!(
            st.cooperative_s <= st.classical_s + 1e-9,
            "S={s}K: coop {} > classical {}",
            st.cooperative_s,
            st.classical_s
        );
    }
}

#[test]
fn speedup_grows_with_offloaded_fraction() {
    let spec = VoltaSpec::default();
    let mut last = 1.0f64;
    for s in [32u64, 128, 256] {
        let dep = Deployment::v100_node(PANGU_38B, s * 1024, 50);
        let p = plan(&dep);
        let st = step_latency(&spec, &dep, &p);
        let sp = st.classical_s / st.cooperative_s;
        assert!(sp >= last * 0.98, "S={s}K: {sp:.3} < {last:.3}");
        last = sp;
    }
    assert!(last > 1.25, "max speedup {last:.2}");
}

#[test]
fn real_tiled_allreduce_matches_serial_under_load() {
    // Larger-scale numeric check of the threaded ring with compute delays.
    let compute: Box<BlockCompute> = Box::new(|b, buf| {
        for (i, v) in buf.iter_mut().enumerate() {
            *v = ((b + 1) * (i % 251)) as f32 * 1e-3;
        }
    });
    let serial =
        serial_all_reduce(8, 4096, 5, &compute, Duration::from_micros(200)).unwrap();
    let tiled =
        tiled_all_reduce(8, 4096, 5, &compute, Duration::from_micros(200)).unwrap();
    assert_eq!(serial.len(), tiled.len());
    for (i, (a, b)) in serial.iter().zip(&tiled).enumerate() {
        assert!((a - b).abs() < 1e-4, "elem {i}: {a} vs {b}");
    }
}
