//! Integration: the unified KV reclamation subsystem — swap-out
//! preemption, host→device promotion, and cost-aware victim selection.
//!
//! Runs the full engine stack over [`HostModelBackend`] (no artifacts
//! needed) with the device tier forced small, and pins the acceptance
//! property of the reclamation PR: serving with swap-out/restore and
//! promotion enabled is **token-identical** to the recompute path and
//! to an unconstrained engine, over random preemption/promotion
//! schedules × page-size-shaping device budgets × GQA configs × thread
//! counts; swap-out strictly reduces replayed prefill work; suspended
//! sequences resume before new admissions; and no page is ever leaked
//! on either tier.

use fastattn::attention::batch::ParallelConfig;
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout, PreemptMode,
    VictimPolicy,
};
use fastattn::models::ModelShape;
use fastattn::prop_ensure;
use fastattn::proptest::check;

/// tiny_gqa geometry: layers 2 × kv_heads 2 → a block group is 4 pages
/// of 2·4·16·8 B = 1 KiB each at page_size 16.
const GROUP_BYTES: usize = 4 * 1024;

fn reclaim_engine(
    device_groups: usize,
    host_groups: usize,
    mode: PreemptMode,
    policy: VictimPolicy,
    threads: usize,
) -> Engine {
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        device_kv_budget: device_groups * GROUP_BYTES,
        host_kv_budget: host_groups * GROUP_BYTES,
        page_size: 16,
        preempt_mode: mode,
        victim_policy: policy,
        ..EngineConfig::default()
    };
    Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    )
}

fn run(e: &mut Engine, prompts: &[Vec<i32>], p: GenParams) -> Vec<Vec<i32>> {
    for pr in prompts {
        e.submit(pr.clone(), p).unwrap();
    }
    let mut out = e.run_until_idle().unwrap();
    out.sort_by_key(|r| r.id);
    out.into_iter().map(|r| r.tokens).collect()
}

/// Acceptance property: over random GQA shapes, page sizes, thread
/// counts, device/host budgets, victim policies and preemption modes,
/// a pressure-squeezed engine (random schedules of swap-outs, resumes,
/// promotions, migrations and recompute preemptions) generates exactly
/// the tokens of an unconstrained engine — and drains both tiers.
#[test]
fn prop_reclaim_schedules_are_token_identical() {
    let mut total_swaps = 0u64;
    let mut total_resumes = 0u64;
    let mut total_preemptions = 0u64;
    let mut case = 0usize;
    check(10, |rng| {
        let (heads, kvh) = *rng.pick(&[(2u32, 1u32), (4, 2), (4, 4), (6, 2)]);
        let model = ModelShape {
            name: "reclaim-prop",
            params: 0,
            layers: rng.range(1, 3) as u32,
            heads,
            kv_heads: kvh,
            head_dim: *rng.pick(&[4u32, 8]),
            ffn: 32,
            vocab: 64,
        };
        let max_seq = 96;
        let page_size = rng.range(2, 9);
        let threads = rng.range(1, 5);
        // cycle modes and policies deterministically so every case set
        // covers all of them (a random pick could miss one)
        let mode = [PreemptMode::Swap, PreemptMode::Auto, PreemptMode::Recompute][case % 3];
        let policy = [
            VictimPolicy::Youngest,
            VictimPolicy::FewestPagesLost,
            VictimPolicy::ClosestToDone,
        ][(case / 3) % 3];
        case += 1;

        // at least three concurrent sequences over tiers that cannot
        // hold all of them: usable < n × need, so once the third
        // admission lands (host-backed gate lets it in early) the
        // engine is provably over-committed and the ladder must
        // preempt — every case exercises the swap/recompute rungs.
        let n = rng.range(3, 5);
        let prompts: Vec<Vec<i32>> = (0..n)
            .map(|i| {
                let len = rng.range(4, 25);
                (0..len).map(|t| ((t * 7 + i * 13) % 64) as i32).collect()
            })
            .collect();
        let max_new = rng.range(8, 25);
        let gp = GenParams { max_new_tokens: max_new, eos_token: None, share_prefix: false };

        // the worst-case block demand of the biggest request, in groups
        let longest = prompts.iter().map(|p| p.len()).max().unwrap() + max_new;
        let need_groups = longest.div_ceil(page_size);
        // device cannot hold two requests; device+host holds any one
        // but never three (usable ≤ 3·need − 1 < n·need)
        let device_groups = rng.range(1, need_groups + 1);
        let host_groups = need_groups + rng.range(1, need_groups.max(2));

        let group_bytes = model.layers as usize
            * kvh as usize
            * 2
            * 4
            * page_size
            * model.head_dim as usize;
        let mk = |dev: usize, host: usize, m: PreemptMode, pol: VictimPolicy| {
            let cfg = EngineConfig {
                parallel: ParallelConfig { threads, min_work_per_thread: 0 },
                kv_layout: KvLayout::Paged,
                device_kv_budget: dev * group_bytes,
                host_kv_budget: host * group_bytes,
                page_size,
                preempt_mode: m,
                victim_policy: pol,
                ..EngineConfig::default()
            };
            Engine::with_backend(
                Box::new(HostModelBackend::new(HostModelConfig::for_shape(model, max_seq))),
                cfg,
            )
        };

        let mut base = mk(64 * need_groups, 0, PreemptMode::Recompute, VictimPolicy::Youngest);
        let want = run(&mut base, &prompts, gp);
        prop_ensure!(base.metrics.preemptions == 0, "unconstrained run never preempts");

        let mut e = mk(device_groups, host_groups, mode, policy);
        let got = run(&mut e, &prompts, gp);
        prop_ensure!(
            got == want,
            "reclamation changed tokens (mode={mode:?} policy={policy:?} dev={device_groups} \
             host={host_groups} page_size={page_size} threads={threads})"
        );
        let m = &e.metrics;
        prop_ensure!(m.pages_used == 0, "device pages leaked: {}", m.pages_used);
        prop_ensure!(m.host_pages_used == 0, "host pages leaked: {}", m.host_pages_used);
        prop_ensure!(
            m.swaps_in == m.swaps_out,
            "every swapped sequence must resume: {} out vs {} in",
            m.swaps_out,
            m.swaps_in
        );
        prop_ensure!(m.swaps_out <= m.preemptions, "swaps are a preemption subset");
        total_swaps += m.swaps_out;
        total_resumes += m.swaps_in;
        total_preemptions += m.preemptions;
        Ok(())
    });
    // over-commitment is built into every case, so preemption must
    // have fired; swap-out coverage is pinned by the deterministic
    // tests below (whether a given random squeeze swaps or recomputes
    // depends on how much host room migrations left the victim).
    assert!(total_preemptions > 0, "no case ever exercised preemption");
    assert_eq!(total_swaps, total_resumes, "every swap must have resumed");
}

/// Swap-out beats recompute on work replayed: under the same squeeze,
/// the Swap engine never prefills a prompt token twice, while the
/// Recompute engine must replay — tokens identical either way.
#[test]
fn swap_mode_eliminates_replay_under_squeeze() {
    let p = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
    let prompts: Vec<Vec<i32>> = vec![vec![1; 8], vec![2; 8], vec![3; 8]];
    let prompt_tokens: u64 = prompts.iter().map(|x| x.len() as u64).sum();

    let mut base = reclaim_engine(64, 0, PreemptMode::Recompute, VictimPolicy::Youngest, 1);
    let want = run(&mut base, &prompts, p);

    let mut swap = reclaim_engine(2, 2, PreemptMode::Swap, VictimPolicy::Youngest, 1);
    let got = run(&mut swap, &prompts, p);
    assert_eq!(got, want, "swap-out must not change tokens");
    let sm = &swap.metrics;
    assert!(sm.swaps_out >= 1, "the squeeze must swap sequences out");
    assert_eq!(sm.swaps_in, sm.swaps_out);
    assert_eq!(
        sm.prefilled_tokens, prompt_tokens,
        "swap-out preserves cached KV: no prompt token prefills twice"
    );
    assert!(sm.recompute_tokens_avoided > 0);

    let mut rec = reclaim_engine(2, 2, PreemptMode::Recompute, VictimPolicy::Youngest, 1);
    let got_r = run(&mut rec, &prompts, p);
    assert_eq!(got_r, want, "recompute must not change tokens");
    let rm = &rec.metrics;
    assert_eq!(rm.swaps_out, 0);
    assert!(rm.preemptions >= 1);
    assert!(
        rm.prefilled_tokens > prompt_tokens,
        "recompute replays prefill work: {} !> {}",
        rm.prefilled_tokens,
        prompt_tokens
    );
}

/// Thread count must not change tokens when the run is squeezed
/// through swaps, resumes and promotions (the reclamation
/// generalization of the threads-invariance law).
#[test]
fn reclaim_is_thread_invariant() {
    let p = GenParams { max_new_tokens: 24, eos_token: None, share_prefix: false };
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| (0..(i * 7 + 4) % 20 + 2).map(|t| ((t * 5 + i) % 64) as i32).collect())
        .collect();
    let run_t = |threads: usize| {
        let mut e = reclaim_engine(2, 4, PreemptMode::Swap, VictimPolicy::Youngest, threads);
        run(&mut e, &prompts, p)
    };
    let one = run_t(1);
    let four = run_t(4);
    assert_eq!(one, four, "threads must not change reclaimed tokens");
    let mut base = reclaim_engine(64, 0, PreemptMode::Recompute, VictimPolicy::Youngest, 4);
    assert_eq!(one, run(&mut base, &prompts, p), "squeeze must not change tokens");
}

/// Cost-aware victim policies serve every request to completion with
/// tokens identical to the unconstrained engine, and never leak pages
/// — whatever they choose to evict.
#[test]
fn victim_policies_serve_identical_tokens_under_pressure() {
    let p = GenParams { max_new_tokens: 20, eos_token: None, share_prefix: false };
    // deliberately skewed: one long, one medium, one short sequence so
    // the policies actually rank differently
    let prompts: Vec<Vec<i32>> = vec![vec![5; 28], vec![6; 12], vec![7; 4]];
    let mut base = reclaim_engine(64, 0, PreemptMode::Recompute, VictimPolicy::Youngest, 1);
    let want = run(&mut base, &prompts, p);

    for policy in
        [VictimPolicy::Youngest, VictimPolicy::FewestPagesLost, VictimPolicy::ClosestToDone]
    {
        for mode in [PreemptMode::Auto, PreemptMode::Swap, PreemptMode::Recompute] {
            let mut e = reclaim_engine(2, 3, mode, policy, 1);
            let got = run(&mut e, &prompts, p);
            assert_eq!(got, want, "{policy:?}/{mode:?} changed tokens");
            assert_eq!(e.metrics.pages_used, 0, "{policy:?}/{mode:?} leaked device pages");
            assert_eq!(e.metrics.host_pages_used, 0, "{policy:?}/{mode:?} leaked host pages");
        }
    }
}

/// A suspended sequence takes the admission slot back before any new
/// request: completion order is strictly FCFS even when the middle
/// request spent most of its life parked on the host tier.
#[test]
fn suspended_resume_outranks_new_admissions() {
    let p = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
    let mut e = reclaim_engine(2, 2, PreemptMode::Swap, VictimPolicy::Youngest, 1);
    let ids: Vec<_> = (0..3)
        .map(|i| e.submit(vec![i as i32 + 1; 8], p).unwrap())
        .collect();
    let out = e.run_until_idle().unwrap();
    assert_eq!(out.len(), 3);
    assert!(out.iter().all(|r| r.tokens.len() == 40));
    let order: Vec<_> = out.iter().map(|r| r.id).collect();
    assert_eq!(order, ids, "resume must outrank new admission (FCFS preserved)");
    assert!(e.metrics.swaps_out >= 1);
    assert_eq!(e.metrics.swaps_in, e.metrics.swaps_out);
}

/// Promotion pulls a long-lived survivor's cold blocks back onto the
/// device once its neighbor finishes — and the folded cross-sequence
/// migration that preceded it paid the link setup latency once.
#[test]
fn promotion_and_folded_migration_under_contention() {
    let p = GenParams { max_new_tokens: 28, eos_token: None, share_prefix: false };
    let prompts: Vec<Vec<i32>> = vec![vec![7; 20], vec![9; 20]];
    let mut base = reclaim_engine(64, 0, PreemptMode::Recompute, VictimPolicy::Youngest, 1);
    let want = run(&mut base, &prompts, p);

    let mut e = reclaim_engine(4, 4, PreemptMode::Auto, VictimPolicy::Youngest, 1);
    let got = run(&mut e, &prompts, p);
    assert_eq!(got, want, "promotion must not change tokens");
    let m = &e.metrics;
    assert!(m.pages_migrated >= 8, "both sequences' cold blocks migrate");
    assert!(m.grouped_transfers >= 1, "cold groups fold into one transfer");
    assert!(m.promotions >= 1, "freed capacity must pull hot blocks back");
    assert!(m.promoted_pages >= 4);
    assert_eq!(m.preemptions, 0);
    // per-request latency histograms populated (TTFT/TPOT groundwork)
    assert_eq!(m.ttft.count(), 2);
    assert!(m.tpot.count() >= 1);
    assert!(m.ttft.mean_s() > 0.0);
}

/// Early-EOS workloads whose generation budget is a loose upper bound
/// must not be preemption-churned: nominal (worst-case) over-commitment
/// alone doesn't skip the migrate rung — only a host tier too tight to
/// keep the swap reservation does.  With ample host room the ladder
/// keeps every sequence live exactly as the pre-swap migrate-first
/// ladder did.
#[test]
fn ample_host_tier_migrates_instead_of_preempting_eos_workloads() {
    // learn the greedy continuation, then stop everything at its 5th
    // token: worst case is 8 + 80 = 88 tokens = 6 groups per request
    // (nominally over-committed: 3 × 6 > 2 + 10 usable), actual demand
    // is one group each.
    let prompt = vec![11i32; 8];
    let mut probe = reclaim_engine(64, 0, PreemptMode::Recompute, VictimPolicy::Youngest, 1);
    let probe_gp = GenParams { max_new_tokens: 8, eos_token: None, share_prefix: false };
    probe.submit(prompt.clone(), probe_gp).unwrap();
    let eos = probe.run_until_idle().unwrap()[0].tokens[4];

    let p = GenParams { max_new_tokens: 80, eos_token: Some(eos), share_prefix: false };
    let mut base = reclaim_engine(64, 0, PreemptMode::Recompute, VictimPolicy::Youngest, 1);
    let want = run(&mut base, &[prompt.clone(), prompt.clone(), prompt.clone()], p);

    let mut e = reclaim_engine(2, 10, PreemptMode::Auto, VictimPolicy::Youngest, 1);
    let got = run(&mut e, &[prompt.clone(), prompt.clone(), prompt], p);
    assert_eq!(got, want, "reservation-gated ladder must not change tokens");
    assert_eq!(
        e.metrics.preemptions, 0,
        "an ample host tier must absorb a loose-budget workload without preemption"
    );
    assert_eq!(e.metrics.swaps_out, 0);
    assert_eq!(e.metrics.pages_used, 0);
    assert_eq!(e.metrics.host_pages_used, 0);
}

/// The no-livelock invariant under a sustained many-request squeeze:
/// every request completes, FCFS order is preserved for equal-length
/// work, and both tiers drain — across all preemption modes.
#[test]
fn sustained_squeeze_never_livelocks() {
    for mode in [PreemptMode::Auto, PreemptMode::Swap, PreemptMode::Recompute] {
        let p = GenParams { max_new_tokens: 12, eos_token: None, share_prefix: false };
        let prompts: Vec<Vec<i32>> = (0..10)
            .map(|i| (0..(i * 5 + 3) % 28 + 1).map(|t| ((t * 3 + i) % 64) as i32).collect())
            .collect();
        let mut e = reclaim_engine(2, 4, mode, VictimPolicy::FewestPagesLost, 1);
        let got = run(&mut e, &prompts, p);
        assert_eq!(got.len(), 10, "{mode:?} lost a request");
        assert!(got.iter().all(|t| t.len() == 12), "{mode:?} under-generated");
        assert_eq!(e.metrics.completed, 10);
        assert_eq!(e.metrics.pages_used, 0, "{mode:?} leaked device pages");
        assert_eq!(e.metrics.host_pages_used, 0, "{mode:?} leaked host pages");

        let mut base = reclaim_engine(64, 0, PreemptMode::Recompute, VictimPolicy::Youngest, 1);
        let want = run(&mut base, &prompts, p);
        assert_eq!(got, want, "{mode:?} changed tokens under sustained pressure");
    }
}
