//! Integration: speculative decoding through the paged KV stack —
//! draft–verify with O(1) page rollback.
//!
//! Runs the full engine over [`HostModelBackend`] (no artifacts
//! needed) and pins the acceptance property of the speculation PR:
//! greedy decode with `speculate = k` is **token-for-token identical**
//! to vanilla greedy decode (`speculate = 0`) across draft depths
//! {1, 2, 4, 8} × codecs {F32, Int8} × shared-prefix on/off × threads
//! {1, 4} × page sizes, and composes with the tiered cache's
//! offload/preemption machinery without changing tokens.  Rollback
//! accounting stays exact — pages popped never exceed pages
//! speculatively written, the accept histogram accounts for every
//! decoded token, and no page leaks at idle — and the streaming feed
//! stays gap-free when one verify step emits several tokens at once.

use fastattn::attention::batch::ParallelConfig;
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout, PageCodec,
};
use fastattn::models::ModelShape;
use fastattn::prop_ensure;
use fastattn::proptest::check;

/// Acceptance property: speculative decode is token-identical to
/// vanilla greedy decode over random draft depths, page sizes, codecs,
/// GQA configs, sharing modes and thread counts — and the cases
/// collectively exercise both draft acceptance and rejection rollback.
#[test]
fn prop_spec_decode_equals_vanilla_greedy() {
    let mut total_accepted = 0u64;
    let mut total_rollback = 0u64;
    check(14, |rng| {
        let (heads, kvh) = *rng.pick(&[(2u32, 1u32), (4, 2), (4, 4)]);
        let model = ModelShape {
            name: "spec-prop",
            params: 0,
            layers: rng.range(1, 3) as u32,
            heads,
            kv_heads: kvh,
            head_dim: *rng.pick(&[4u32, 8]),
            ffn: 32,
            vocab: 64,
        };
        let max_seq = 96;
        let page_size = *rng.pick(&[1usize, 2, 4, 16]);
        let threads = *rng.pick(&[1usize, 4]);
        let codec = if rng.bool() { PageCodec::Int8 } else { PageCodec::F32 };
        let depth = *rng.pick(&[1usize, 2, 4, 8]);
        let share = rng.bool();
        let max_new = rng.range(4, 17);
        let eos = if rng.bool() { Some(rng.below(64) as i32) } else { None };

        // a mix of repetitive prompts (prompt lookup proposes real
        // continuations, and greedy decode tends to settle into cycles
        // the drafter then rides) and fresh ones (the drafter mostly
        // misses and every proposal rolls back)
        let n = rng.range(2, 5);
        let system: Vec<i32> = (0..rng.range(4, 13)).map(|_| rng.below(64) as i32).collect();
        let prompts: Vec<Vec<i32>> = (0..n)
            .map(|_| {
                let mut p = if share { system.clone() } else { Vec::new() };
                if rng.bool() {
                    let period = rng.range(1, 4);
                    let phrase: Vec<i32> = (0..period).map(|_| rng.below(64) as i32).collect();
                    for t in 0..rng.range(6, 20) {
                        p.push(phrase[t % period]);
                    }
                } else {
                    for _ in 0..rng.range(1, 16) {
                        p.push(rng.below(64) as i32);
                    }
                }
                p
            })
            .collect();

        let run = |speculate: usize| {
            let cfg = EngineConfig {
                parallel: ParallelConfig { threads, min_work_per_thread: 0 },
                kv_layout: KvLayout::Paged,
                page_size,
                kv_codec: codec,
                speculate,
                ..EngineConfig::default()
            };
            let mut e = Engine::with_backend(
                Box::new(HostModelBackend::new(HostModelConfig::for_shape(model, max_seq))),
                cfg,
            );
            for pr in &prompts {
                let gp = GenParams {
                    max_new_tokens: max_new,
                    eos_token: eos,
                    share_prefix: share,
                };
                e.submit(pr.clone(), gp).unwrap();
            }
            let mut out = e.run_until_idle().unwrap();
            out.sort_by_key(|r| r.id);
            let toks: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
            (toks, e.metrics.clone())
        };
        let (base, bm) = run(0);
        let (spec, sm) = run(depth);
        prop_ensure!(
            base == spec,
            "speculation changed tokens (depth={depth} page_size={page_size} codec={codec:?} \
             share={share} threads={threads} heads={heads} kvh={kvh} layers={})",
            model.layers
        );
        prop_ensure!(
            bm.draft_proposed == 0 && bm.spec_pages_written == 0,
            "vanilla engine must never draft"
        );
        prop_ensure!(
            sm.draft_accepted <= sm.draft_proposed,
            "accepted {} of {} proposed drafts",
            sm.draft_accepted,
            sm.draft_proposed
        );
        prop_ensure!(
            sm.spec_rollback_pages <= sm.spec_pages_written,
            "rolled back {} of {} speculatively written pages",
            sm.spec_rollback_pages,
            sm.spec_pages_written
        );
        // every decoded token was emitted by exactly one verify step
        let hist_tokens: u64 = sm
            .accept_len_hist
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as u64 + 1) * c)
            .sum();
        prop_ensure!(
            hist_tokens == sm.decoded_tokens,
            "accept histogram counts {hist_tokens} tokens, engine decoded {}",
            sm.decoded_tokens
        );
        // at idle only prefix-cache runs stay resident — every
        // rejected-draft page went back to the free list
        prop_ensure!(
            sm.pages_used == sm.shared_pages,
            "page leak at idle: {} used vs {} prefix-cache pages",
            sm.pages_used,
            sm.shared_pages
        );
        total_accepted += sm.draft_accepted;
        total_rollback += sm.spec_rollback_pages;
        Ok(())
    });
    assert!(total_accepted > 0, "no case ever accepted a draft token");
    assert!(total_rollback > 0, "no case ever rolled back a rejected draft page");
}

/// Speculation composes with the rest of the paged machinery: under
/// device pressure (offload, swap-out, recompute preemption) the
/// speculative engine still generates exactly the tokens of an
/// unconstrained vanilla run, and both tiers drain at idle.
#[test]
fn speculation_survives_offload_and_preemption_pressure() {
    // tiny_gqa geometry: a block group is layers 2 × kv_heads 2 = 4
    // pages of 1 KiB each → 4 KiB per group.
    let group_bytes = 4 * 1024usize;
    let system = vec![21i32; 24];
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| {
            let mut p = system.clone();
            p.extend(vec![i as i32 + 40; 3]);
            p
        })
        .collect();
    let gp = GenParams { max_new_tokens: 16, eos_token: None, share_prefix: false };

    // unconstrained vanilla reference
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        page_size: 16,
        ..EngineConfig::default()
    };
    let mut big = Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    );
    for pr in &prompts {
        big.submit(pr.clone(), gp).unwrap();
    }
    let mut want = big.run_until_idle().unwrap();
    want.sort_by_key(|r| r.id);

    // constrained + speculative: 4 device groups, 8 host groups
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        device_kv_budget: 4 * group_bytes,
        host_kv_budget: 8 * group_bytes,
        page_size: 16,
        speculate: 4,
        ..EngineConfig::default()
    };
    let mut e = Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    );
    for pr in &prompts {
        e.submit(pr.clone(), gp).unwrap();
    }
    let mut out = e.run_until_idle().unwrap();
    out.sort_by_key(|r| r.id);

    assert_eq!(out.len(), want.len());
    for (a, b) in out.iter().zip(&want) {
        assert_eq!(
            a.tokens, b.tokens,
            "speculation + offload + preemption changed request {} tokens",
            a.id
        );
    }
    let m = &e.metrics;
    assert!(m.draft_proposed > 0, "repetitive prompts must draw proposals");
    assert!(m.spec_rollback_pages <= m.spec_pages_written);
    assert_eq!(m.pages_used, 0, "device pages released at idle");
    assert_eq!(m.host_pages_used, 0, "host tier drained at idle");
}

/// The streaming feed stays gap-free under speculation: a verify step
/// emits up to k+1 `TokenEvent`s at once, with contiguous indices that
/// reassemble exactly into the final response.
#[test]
fn spec_token_events_stream_gap_free() {
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        page_size: 4,
        speculate: 4,
        ..EngineConfig::default()
    };
    let mut e = Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    );
    // strongly periodic prompt so the drafter proposes every step
    let prompt: Vec<i32> = (0..24).map(|t| (t % 3) as i32 + 7).collect();
    let id = e
        .submit(prompt, GenParams { max_new_tokens: 24, eos_token: None, share_prefix: false })
        .unwrap();
    let mut seen: Vec<(usize, i32)> = Vec::new();
    loop {
        let more = e.step().unwrap();
        for ev in e.take_token_events() {
            assert_eq!(ev.id, id);
            // replayed tokens (recompute preemption) carry original
            // indices; deduplicate like the serving plane does
            if seen.iter().all(|&(i, _)| i != ev.index) {
                seen.push((ev.index, ev.token));
            }
        }
        if !more {
            break;
        }
    }
    let out = e.take_finished();
    assert_eq!(out.len(), 1);
    seen.sort_by_key(|&(i, _)| i);
    for (want, &(got, _)) in seen.iter().enumerate() {
        assert_eq!(got, want, "gap in streamed indices");
    }
    let streamed: Vec<i32> = seen.iter().map(|&(_, t)| t).collect();
    assert_eq!(streamed, out[0].tokens, "streamed tokens must reassemble the response");
    assert!(e.metrics.draft_proposed > 0, "periodic prompt must draw proposals");
}

/// Client cancel composes with speculation: a mid-generation abort
/// releases every page (including any speculatively written this step)
/// and the remaining request still completes normally.
#[test]
fn cancel_under_speculation_frees_pages() {
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        page_size: 4,
        speculate: 4,
        ..EngineConfig::default()
    };
    let mut e = Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    );
    let long: Vec<i32> = (0..12).map(|t| (t % 2) as i32 + 3).collect();
    let a = e
        .submit(long, GenParams { max_new_tokens: 64, eos_token: None, share_prefix: false })
        .unwrap();
    let b = e
        .submit(vec![9; 8], GenParams { max_new_tokens: 4, eos_token: None, share_prefix: false })
        .unwrap();
    // run a few steps so both sequences are live and hold pages
    for _ in 0..6 {
        e.step().unwrap();
    }
    assert!(e.cancel(a), "live request must cancel");
    assert!(!e.cancel(a), "double cancel is a no-op");
    let out = e.run_until_idle().unwrap();
    assert_eq!(out.len(), 1, "the cancelled request produces no response");
    assert_eq!(out[0].id, b);
    assert_eq!(e.metrics.pages_used, 0, "cancel released every page");
}
