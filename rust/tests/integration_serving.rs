//! Integration: the continuous-batching request plane.
//!
//! Runs the full serving stack over [`HostModelBackend`] (no artifacts
//! needed) and pins the PR's acceptance properties:
//!
//! * **streaming parity** — for every request, the streamed token
//!   sequence equals the final `Response.tokens` bit-for-bit, across
//!   thread counts × paged/tiered/recompute-squeezed pools × shard
//!   counts, under preemption/swap schedules (replayed tokens after a
//!   recompute preemption must also be bit-identical);
//! * **packing parity** — token-budget admission (chunk rows of
//!   several sequences packed into one forward pass) generates exactly
//!   the tokens of one-sequence-per-step bucket admission;
//! * **the no-hang contract** — every submitted request terminates
//!   with tokens or a typed error, through the `Server` front-end;
//! * **SLO-aware admission** — with a TPOT objective in place the
//!   engine defers new prefills, and still completes everything.
//!
//! The artifact-backed scenarios at the bottom need `rust/artifacts/`
//! and are `#[ignore]`d instead of silently passing.

use std::collections::HashMap;

use fastattn::attention::batch::ParallelConfig;
use fastattn::coordinator::kv_cache::kv_page_bytes_codec;
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout, PageCodec,
    PreemptMode, RequestId, ServeError, Server, ServerConfig, ShardedBackend, ShardedConfig,
    StreamEvent,
};
use fastattn::runtime::Runtime;

/// tiny_gqa geometry: layers 2 × kv_heads 2 → a block group is 4 pages
/// of 2·4·16·8 B = 1 KiB each at page_size 16.
const GROUP_BYTES: usize = 4 * 1024;

/// How the KV pools are squeezed (which reclamation rungs can fire).
#[derive(Clone, Copy, Debug)]
enum Pool {
    /// Default budgets: no pressure, no preemption.
    Unconstrained,
    /// Small device tier + host tier: migration and swap-out/resume.
    Tiered { dev_groups: usize, host_groups: usize },
    /// Small device tier, no host tier: recompute preemption (token
    /// replay through the streaming feed).
    Recompute { dev_groups: usize },
}

fn engine_for(pool: Pool, threads: usize, shards: usize) -> Engine {
    engine_for_codec(pool, threads, shards, PageCodec::F32)
}

/// `engine_for` with an explicit on-page codec; budgets are sized in
/// block groups *of that codec* so the squeeze dynamics match the f32
/// cells (int8 groups are ~4× smaller in bytes).
fn engine_for_codec(pool: Pool, threads: usize, shards: usize, codec: PageCodec) -> Engine {
    let group_bytes = 4 * kv_page_bytes_codec(16, 8, codec);
    debug_assert!(codec != PageCodec::F32 || group_bytes == GROUP_BYTES);
    let mut cfg = EngineConfig {
        parallel: ParallelConfig { threads, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        page_size: 16,
        preempt_mode: PreemptMode::Auto,
        kv_codec: codec,
        ..EngineConfig::default()
    };
    match pool {
        Pool::Unconstrained => {}
        Pool::Tiered { dev_groups, host_groups } => {
            cfg.device_kv_budget = dev_groups * group_bytes;
            cfg.host_kv_budget = host_groups * group_bytes;
        }
        Pool::Recompute { dev_groups } => {
            cfg.device_kv_budget = dev_groups * group_bytes;
            cfg.host_kv_budget = 0;
        }
    }
    let host = HostModelConfig::tiny_gqa();
    if shards == 1 {
        Engine::with_backend(Box::new(HostModelBackend::new(host)), cfg)
    } else {
        Engine::with_backend(
            Box::new(ShardedBackend::new(host, ShardedConfig::for_shards(shards)).unwrap()),
            cfg,
        )
    }
}

/// Drive the engine to idle while collecting the streaming feed, and
/// assert per-token stream integrity on the way: indices are gap-free
/// and any replayed token (recompute preemption) is bit-identical to
/// what was first streamed.  Returns (streamed, final) token vectors
/// keyed by request.
fn stream_to_idle(
    e: &mut Engine,
) -> (HashMap<RequestId, Vec<i32>>, HashMap<RequestId, Vec<i32>>) {
    let mut streamed: HashMap<RequestId, Vec<i32>> = HashMap::new();
    let mut finals: HashMap<RequestId, Vec<i32>> = HashMap::new();
    loop {
        let more = e.step().unwrap();
        for ev in e.take_token_events() {
            let s = streamed.entry(ev.id).or_default();
            if ev.index == s.len() {
                s.push(ev.token);
            } else {
                assert!(ev.index < s.len(), "stream of {} skipped an index", ev.id);
                assert_eq!(
                    s[ev.index], ev.token,
                    "request {} replayed token {} with a different value",
                    ev.id, ev.index
                );
            }
        }
        for r in e.take_finished() {
            finals.insert(r.id, r.tokens);
        }
        if !more {
            break;
        }
    }
    (streamed, finals)
}

/// Mixed workload: prompts from shorter than a page to longer than a
/// chunk (max_chunk = 32 for tiny_gqa), mixed generation lengths.
fn workload() -> Vec<(Vec<i32>, GenParams)> {
    (0..10usize)
        .map(|i| {
            let len = 3 + (i * 9) % 45;
            let prompt: Vec<i32> =
                (0..len).map(|j| ((i * 31 + j * 13) % 60) as i32 + 1).collect();
            let gen = 2 + (i * 5) % 12;
            (prompt, GenParams { max_new_tokens: gen, ..GenParams::default() })
        })
        .collect()
}

#[test]
fn streaming_parity_across_pools_threads_shards() {
    for &threads in &[1usize, 4] {
        for &shards in &[1usize, 2] {
            for &pool in &[
                Pool::Unconstrained,
                Pool::Tiered { dev_groups: 4, host_groups: 8 },
                Pool::Recompute { dev_groups: 4 },
            ] {
                let mut e = engine_for(pool, threads, shards);
                for (prompt, p) in workload() {
                    e.submit(prompt, p).unwrap();
                }
                let (streamed, finals) = stream_to_idle(&mut e);
                assert_eq!(finals.len(), 10, "{pool:?} t{threads} s{shards}: all finish");
                for (id, toks) in &finals {
                    assert_eq!(
                        streamed.get(id),
                        Some(toks),
                        "{pool:?} t{threads} s{shards}: stream != final for request {id}"
                    );
                }
                if !matches!(pool, Pool::Unconstrained) {
                    assert!(
                        e.metrics.preemptions > 0,
                        "{pool:?} t{threads} s{shards}: squeeze must actually preempt"
                    );
                }
            }
        }
    }
}

/// The codec × request-plane cell: int8 KV pages under the
/// recompute-squeeze.  Quantized serving is deterministic, so a
/// preempted sequence's prompt replay regenerates (and re-streams)
/// exactly the tokens it first produced, and the squeezed engine's
/// tokens equal an unconstrained int8 engine's.
#[test]
fn streaming_parity_int8_under_recompute_squeeze() {
    for &threads in &[1usize, 4] {
        // unconstrained int8 reference tokens
        let mut free = engine_for_codec(Pool::Unconstrained, threads, 1, PageCodec::Int8);
        for (prompt, p) in workload() {
            free.submit(prompt, p).unwrap();
        }
        let (_, want) = stream_to_idle(&mut free);

        let squeeze = Pool::Recompute { dev_groups: 4 };
        let mut e = engine_for_codec(squeeze, threads, 1, PageCodec::Int8);
        for (prompt, p) in workload() {
            e.submit(prompt, p).unwrap();
        }
        let (streamed, finals) = stream_to_idle(&mut e);
        assert_eq!(finals.len(), 10, "t{threads}: all int8 requests finish");
        for (id, toks) in &finals {
            assert_eq!(
                streamed.get(id),
                Some(toks),
                "t{threads}: int8 stream != final for request {id}"
            );
            assert_eq!(
                want.get(id),
                Some(toks),
                "t{threads}: recompute squeeze changed int8 tokens of request {id}"
            );
        }
        assert!(e.metrics.preemptions > 0, "t{threads}: squeeze must actually preempt");
        assert!(e.metrics.dequant_rows > 0, "t{threads}: int8 gather must dequantize");
    }
}

/// Token-budget packed admission is bit-identical to bucket-style
/// one-sequence-per-prefill-step admission: packing chunk rows of
/// several sequences into one forward pass must not change any token.
#[test]
fn packed_prefill_matches_bucket_admission() {
    let run = |prefill_budget: usize| -> Vec<(RequestId, Vec<i32>)> {
        let cfg = EngineConfig {
            kv_layout: KvLayout::Paged,
            max_batch_prefill_tokens: prefill_budget,
            ..EngineConfig::default()
        };
        let mut e = Engine::with_backend(
            Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
            cfg,
        );
        for (prompt, p) in workload() {
            e.submit(prompt, p).unwrap();
        }
        let mut out = e.run_until_idle().unwrap();
        out.sort_by_key(|r| r.id);
        out.into_iter().map(|r| (r.id, r.tokens)).collect()
    };
    // budget 1 → one sequence per prefill step (the old bucket shape);
    // 0 → one max_chunk (the packing default); 64 → two chunks' worth
    let bucket = run(1);
    assert_eq!(bucket, run(0), "default packing diverged from bucket admission");
    assert_eq!(bucket, run(64), "wide packing diverged from bucket admission");
}

/// Packing actually happens: short admissions share one forward pass,
/// so batched chunk rows exceed batched chunk steps.
#[test]
fn packed_prefill_packs_multiple_rows_per_step() {
    let mut e = engine_for(Pool::Unconstrained, 1, 1);
    for _ in 0..4 {
        // four 8-token prompts — all four first chunks fit one 32-token
        // prefill budget
        e.submit(vec![5; 8], GenParams { max_new_tokens: 4, ..GenParams::default() })
            .unwrap();
    }
    let out = e.run_until_idle().unwrap();
    assert_eq!(out.len(), 4);
    assert!(
        e.metrics.chunk_rows > e.metrics.chunk_steps,
        "expected packed chunk rows ({}) > batched steps ({})",
        e.metrics.chunk_rows,
        e.metrics.chunk_steps
    );
    assert!(e.metrics.mean_chunk_batch() > 1.0);
}

/// `max_batch_total_tokens` defers admissions but changes no tokens.
#[test]
fn total_token_budget_defers_but_preserves_tokens() {
    let run = |total: usize| -> Vec<Vec<i32>> {
        let cfg = EngineConfig {
            kv_layout: KvLayout::Paged,
            max_batch_total_tokens: total,
            ..EngineConfig::default()
        };
        let mut e = Engine::with_backend(
            Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
            cfg,
        );
        for (prompt, p) in workload() {
            e.submit(prompt, p).unwrap();
        }
        let mut out = e.run_until_idle().unwrap();
        out.sort_by_key(|r| r.id);
        out.into_iter().map(|r| r.tokens).collect()
    };
    assert_eq!(run(0), run(80), "serialized admission changed tokens");
}

/// With a TPOT objective that every step violates, the engine defers
/// new prefills while decoding — and still completes everything
/// (deferral never applies when nothing is active, and starvation
/// overrides it).
#[test]
fn slo_deferral_fires_and_everything_completes() {
    let cfg = EngineConfig {
        kv_layout: KvLayout::Paged,
        tpot_slo_s: Some(0.0), // any real step breaches it
        waiting_served_ratio: 1e9, // never declare starvation
        ..EngineConfig::default()
    };
    let mut e = Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    );
    let p = GenParams { max_new_tokens: 24, ..GenParams::default() };
    e.submit(vec![1; 8], p).unwrap();
    // warm the decode window, then pile on admissions
    for _ in 0..8 {
        e.step().unwrap();
    }
    for i in 0..4 {
        e.submit(vec![i + 2; 8], p).unwrap();
    }
    let out = e.run_until_idle().unwrap();
    assert_eq!(out.len(), 5, "SLO deferral must not strand requests");
    assert!(
        e.metrics.slo_deferrals > 0,
        "TPOT objective of 0 must defer at least one prefill"
    );
}

/// End-to-end through the threaded front-end: mixed workload, every
/// stream terminates (no-hang), streamed == final for every request.
#[test]
fn server_streams_match_finals_end_to_end() {
    let server = Server::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        EngineConfig::default(),
        ServerConfig::default(),
    );
    let streams: Vec<_> = workload()
        .into_iter()
        .map(|(prompt, p)| server.submit(prompt, p).unwrap())
        .collect();
    for stream in streams {
        let mut got = Vec::new();
        loop {
            match stream.recv_timeout(std::time::Duration::from_secs(60)) {
                Some(StreamEvent::Token { index, token }) => {
                    assert_eq!(index, got.len(), "gap-free indices");
                    got.push(token);
                }
                Some(StreamEvent::Done(resp)) => {
                    assert_eq!(got, resp.tokens, "stream equals final response");
                    break;
                }
                Some(StreamEvent::Error(e)) => panic!("typed error on healthy server: {e}"),
                None => panic!("stream hung — no-hang contract broken"),
            }
        }
    }
    let m = server.metrics().unwrap();
    assert_eq!(m.completed, 10);
}

/// Typed rejection end-to-end: invalid requests come back as values,
/// valid ones keep flowing.
#[test]
fn server_rejections_are_typed_values() {
    let server = Server::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        EngineConfig::default(),
        ServerConfig::default(),
    );
    for bad in [vec![], vec![1; 1000]] {
        match server.submit(bad, GenParams::default()) {
            Err(ServeError::Rejected(_)) => {}
            other => panic!("expected typed rejection, got {other:?}"),
        }
    }
    let ok = server
        .submit(vec![1, 2, 3], GenParams { max_new_tokens: 2, ..GenParams::default() })
        .unwrap();
    assert_eq!(ok.wait().unwrap().tokens.len(), 2);
}

// ---------------------------------------------------------------------
// Artifact-backed scenarios (PJRT runtime): need rust/artifacts/ from
// python/compile/aot.py, so they are ignored rather than silently
// passing when the bundle is absent.
// ---------------------------------------------------------------------

fn artifact_dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

#[test]
#[ignore = "requires artifacts/ bundle (build with python/compile/aot.py)"]
fn full_stack_serving_scenarios() {
    let rt = Runtime::load(artifact_dir()).expect("runtime loads");
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    let mut engine = Engine::new(rt, EngineConfig::default());

    // --- scenario 1: mixed-length burst, all complete -----------------
    let p3 = GenParams { max_new_tokens: 3, eos_token: None, share_prefix: false };
    let mut ids = Vec::new();
    for i in 0..12usize {
        let len = 1 + (i * 11) % 120;
        let prompt: Vec<i32> = (0..len).map(|j| ((i * 37 + j) % 500 + 1) as i32).collect();
        ids.push(engine.submit(prompt, p3).unwrap());
    }
    let out = engine.run_until_idle().unwrap();
    assert_eq!(out.len(), 12);
    let mut got: Vec<_> = out.iter().map(|r| r.id).collect();
    got.sort();
    assert_eq!(got, ids);
    assert!(out.iter().all(|r| r.tokens.len() == 3));
    assert!(out.iter().all(|r| r.tokens.iter().all(|&t| t >= 0 && t < 512)));

    // --- scenario 2: determinism across a second engine pass ----------
    let p6 = GenParams { max_new_tokens: 6, ..GenParams::default() };
    let a = engine.submit(vec![9, 8, 7, 6], p6);
    let out_a = engine.run_until_idle().unwrap();
    let b = engine.submit(vec![9, 8, 7, 6], p6);
    let out_b = engine.run_until_idle().unwrap();
    assert!(a.is_ok() && b.is_ok());
    assert_eq!(out_a[0].tokens, out_b[0].tokens, "same prompt, same greedy tokens");

    // --- scenario 3: interleaved submissions while decoding -----------
    let long = engine
        .submit(vec![5; 100], GenParams { max_new_tokens: 10, ..GenParams::default() })
        .unwrap();
    for _ in 0..3 {
        engine.step().unwrap();
    }
    let late = engine
        .submit(vec![7; 4], GenParams { max_new_tokens: 2, eos_token: None, share_prefix: false })
        .unwrap();
    let out = engine.run_until_idle().unwrap();
    assert_eq!(out.len(), 2);
    assert!(out.iter().any(|r| r.id == long && r.tokens.len() == 10));
    assert!(out.iter().any(|r| r.id == late && r.tokens.len() == 2));

    // --- scenario 4: failure injection — invalid prompts rejected,
    // engine stays healthy
    assert!(engine.submit(vec![], p3).is_err());
    assert!(engine.submit(vec![1; 1000], p3).is_err());
    assert!(engine
        .submit(vec![1; 100], GenParams { max_new_tokens: 100, ..GenParams::default() })
        .is_err());
    let ok = engine.submit(vec![1, 2], p3).unwrap();
    let out = engine.run_until_idle().unwrap();
    assert_eq!(out[0].id, ok);

    // --- metrics sanity -----------------------------------------------
    let m = engine.metrics.clone();
    assert!(m.completed >= 16);
    assert!(m.decode_steps > 0 && m.prefill_steps > 0);
    assert!(m.decode_tps() > 0.0);
    assert!(m.mean_decode_batch() >= 1.0);
}

#[test]
#[ignore = "requires artifacts/ bundle (build with python/compile/aot.py)"]
fn cache_isolation_across_batch_slots() {
    // Two sequences with identical prompts must generate identical tokens
    // whether batched together with others or not — KV slots don't leak.
    let rt = Runtime::load(artifact_dir()).expect("runtime loads");
    let mut engine = Engine::new(rt, EngineConfig::default());
    let p = GenParams { max_new_tokens: 5, eos_token: None, share_prefix: false };

    // twin prompts surrounded by noise
    let twin: Vec<i32> = vec![42, 7, 99, 3];
    let id1 = engine.submit(twin.clone(), p).unwrap();
    engine.submit(vec![13; 50], p).unwrap();
    let id2 = engine.submit(twin.clone(), p).unwrap();
    engine.submit(vec![77; 31], p).unwrap();
    let out = engine.run_until_idle().unwrap();
    let t1 = &out.iter().find(|r| r.id == id1).unwrap().tokens;
    let t2 = &out.iter().find(|r| r.id == id2).unwrap().tokens;
    assert_eq!(t1, t2, "identical prompts diverged across batch slots");
}
