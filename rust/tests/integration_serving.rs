//! Full-stack integration: artifacts → runtime → engine → server.
//!
//! One `Runtime` load per test binary (PJRT compilation is the expensive
//! part); every scenario drives the real three-layer stack.

use fastattn::coordinator::{Engine, EngineConfig, GenParams};
use fastattn::runtime::Runtime;

fn artifact_dir() -> Option<&'static str> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    std::path::Path::new(dir)
        .join("manifest.json")
        .exists()
        .then_some(dir)
}

#[test]
fn full_stack_serving_scenarios() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::load(dir).expect("runtime loads");
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    let mut engine = Engine::new(rt, EngineConfig::default());

    // --- scenario 1: mixed-length burst, all complete -----------------
    let p3 = GenParams { max_new_tokens: 3, eos_token: None, share_prefix: false };
    let mut ids = Vec::new();
    for i in 0..12usize {
        let len = 1 + (i * 11) % 120;
        let prompt: Vec<i32> = (0..len).map(|j| ((i * 37 + j) % 500 + 1) as i32).collect();
        ids.push(engine.submit(prompt, p3).unwrap());
    }
    let out = engine.run_until_idle().unwrap();
    assert_eq!(out.len(), 12);
    let mut got: Vec<_> = out.iter().map(|r| r.id).collect();
    got.sort();
    assert_eq!(got, ids);
    assert!(out.iter().all(|r| r.tokens.len() == 3));
    assert!(out.iter().all(|r| r.tokens.iter().all(|&t| t >= 0 && t < 512)));

    // --- scenario 2: determinism across a second engine pass ----------
    let p6 = GenParams { max_new_tokens: 6, ..GenParams::default() };
    let a = engine.submit(vec![9, 8, 7, 6], p6);
    let out_a = engine.run_until_idle().unwrap();
    let b = engine.submit(vec![9, 8, 7, 6], p6);
    let out_b = engine.run_until_idle().unwrap();
    assert!(a.is_ok() && b.is_ok());
    assert_eq!(out_a[0].tokens, out_b[0].tokens, "same prompt, same greedy tokens");

    // --- scenario 3: interleaved submissions while decoding -----------
    let long = engine
        .submit(vec![5; 100], GenParams { max_new_tokens: 10, ..GenParams::default() })
        .unwrap();
    // step a few times, then inject more work mid-flight
    for _ in 0..3 {
        engine.step().unwrap();
    }
    let late = engine
        .submit(vec![7; 4], GenParams { max_new_tokens: 2, eos_token: None, share_prefix: false })
        .unwrap();
    let out = engine.run_until_idle().unwrap();
    assert_eq!(out.len(), 2);
    assert!(out.iter().any(|r| r.id == long && r.tokens.len() == 10));
    assert!(out.iter().any(|r| r.id == late && r.tokens.len() == 2));

    // --- scenario 4: failure injection — invalid prompts rejected,
    // engine stays healthy
    assert!(engine.submit(vec![], p3).is_err());
    assert!(engine.submit(vec![1; 1000], p3).is_err());
    assert!(engine
        .submit(vec![1; 100], GenParams { max_new_tokens: 100, ..GenParams::default() })
        .is_err());
    let ok = engine.submit(vec![1, 2], p3).unwrap();
    let out = engine.run_until_idle().unwrap();
    assert_eq!(out[0].id, ok);

    // --- metrics sanity -------------------------------------------------
    let m = engine.metrics.clone();
    assert!(m.completed >= 16);
    assert!(m.decode_steps > 0 && m.prefill_steps > 0);
    assert!(m.decode_tps() > 0.0);
    assert!(m.mean_decode_batch() >= 1.0);
}

#[test]
fn cache_isolation_across_batch_slots() {
    // Two sequences with identical prompts must generate identical tokens
    // whether batched together with others or not — KV slots don't leak.
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(dir).expect("runtime loads");
    let mut engine = Engine::new(rt, EngineConfig::default());
    let p = GenParams { max_new_tokens: 5, eos_token: None, share_prefix: false };

    // twin prompts surrounded by noise
    let twin: Vec<i32> = vec![42, 7, 99, 3];
    let id1 = engine.submit(twin.clone(), p).unwrap();
    engine.submit(vec![13; 50], p).unwrap();
    let id2 = engine.submit(twin.clone(), p).unwrap();
    engine.submit(vec![77; 31], p).unwrap();
    let out = engine.run_until_idle().unwrap();
    let t1 = &out.iter().find(|r| r.id == id1).unwrap().tokens;
    let t2 = &out.iter().find(|r| r.id == id2).unwrap().tokens;
    assert_eq!(t1, t2, "identical prompts diverged across batch slots");
}
