//! Integration: cross-sequence shared-prefix KV pages + copy-on-write.
//!
//! Runs the full engine stack over [`HostModelBackend`] (no artifacts
//! needed) and pins the acceptance property of the prefix-sharing PR:
//! decode output with `share_prefix` on is **bit-identical** to the
//! unshared engine across random prefix lengths, page sizes, GQA
//! configs and thread counts; a copy-on-write split after divergence
//! never corrupts a sibling sequence; and sharing composes with the
//! tiered cache's migration/preemption machinery without changing
//! tokens.

use fastattn::attention::batch::ParallelConfig;
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout,
};
use fastattn::models::ModelShape;
use fastattn::prop_ensure;
use fastattn::proptest::check;

fn engine_for(model: ModelShape, max_seq: usize, page_size: usize, threads: usize) -> Engine {
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        page_size,
        ..EngineConfig::default()
    };
    Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::for_shape(model, max_seq))),
        cfg,
    )
}

/// Acceptance property: shared-prefix serving is token-identical to
/// unshared serving over random prefix lengths, page sizes, GQA
/// configs and thread counts, and the cases collectively exercise both
/// the block-chain hit path and the tail-block COW path.
#[test]
fn prop_shared_prefix_engine_parity() {
    let mut total_hits = 0u64;
    let mut total_cows = 0u64;
    let mut total_saved = 0u64;
    check(10, |rng| {
        let (heads, kvh) = *rng.pick(&[(2u32, 1u32), (4, 2), (4, 4), (6, 2)]);
        let model = ModelShape {
            name: "prefix-prop",
            params: 0,
            layers: rng.range(1, 3) as u32,
            heads,
            kv_heads: kvh,
            head_dim: *rng.pick(&[4u32, 8]),
            ffn: 32,
            vocab: 64,
        };
        let max_seq = 64;
        let page_size = rng.range(1, 9);
        let threads = rng.range(1, 5);
        let max_new = rng.range(2, 7);

        // prompts: a common "system" prefix + per-request suffixes,
        // plus one exact duplicate to exercise tail-block sharing
        let common = rng.range(2, 33);
        let system: Vec<i32> = (0..common).map(|_| rng.below(64) as i32).collect();
        let n = rng.range(2, 5);
        let mut prompts: Vec<Vec<i32>> = (0..n)
            .map(|i| {
                let mut p = system.clone();
                let extra = rng.range(0, 9);
                p.extend((0..extra).map(|t| ((t * 7 + i * 13) % 64) as i32));
                p
            })
            .collect();
        prompts.push(prompts[0].clone());

        let run = |share: bool| {
            let mut e = engine_for(model, max_seq, page_size, threads);
            for pr in &prompts {
                let gp = GenParams {
                    max_new_tokens: max_new,
                    eos_token: None,
                    share_prefix: share,
                };
                e.submit(pr.clone(), gp).unwrap();
            }
            let mut out = e.run_until_idle().unwrap();
            out.sort_by_key(|r| r.id);
            let toks: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
            (toks, e.metrics.clone())
        };
        let (base, bm) = run(false);
        let (shared, sm) = run(true);
        prop_ensure!(
            base == shared,
            "sharing changed tokens (heads={heads} kvh={kvh} layers={} \
             page_size={page_size} threads={threads} common={common})",
            model.layers
        );
        prop_ensure!(bm.prefix_hits == 0, "unshared engine must never hit");
        // at idle every sequence has released its pages; whatever is
        // still in use is exactly the prefix cache's retained runs
        prop_ensure!(
            sm.pages_used == sm.shared_pages,
            "sequence pages leaked: {} used at idle vs {} prefix-cache pages",
            sm.pages_used,
            sm.shared_pages
        );
        prop_ensure!(
            sm.prefilled_tokens + sm.prefix_tokens_saved == bm.prefilled_tokens,
            "saved tokens must exactly offset prefill work: {} + {} != {}",
            sm.prefilled_tokens,
            sm.prefix_tokens_saved,
            bm.prefilled_tokens
        );
        total_hits += sm.prefix_hits;
        total_cows += sm.cow_splits;
        total_saved += sm.prefix_tokens_saved;
        Ok(())
    });
    assert!(total_hits > 0, "no case ever hit the prefix cache");
    assert!(total_cows > 0, "no case ever exercised a COW split");
    assert!(total_saved > 0, "sharing never skipped any prefill work");
}

/// Sharing composes with the two-tier cache: a device-constrained
/// engine (cold blocks migrating to the host tier, preemption under
/// pressure, shared pages pinned on device) still generates exactly
/// the tokens of an unconstrained, unshared engine.
#[test]
fn sharing_survives_offload_and_preemption_pressure() {
    // tiny_gqa geometry: a block group is layers 2 × kv_heads 2 = 4
    // pages of 1 KiB each → 4 KiB per group.
    let group_bytes = 4 * 1024usize;
    let system = vec![11i32; 20];
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| {
            let mut p = system.clone();
            p.extend(vec![i as i32 + 30; 4]);
            p
        })
        .collect();
    let gp = |share: bool| GenParams {
        max_new_tokens: 16,
        eos_token: None,
        share_prefix: share,
    };

    // unconstrained, unshared reference
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        ..EngineConfig::default()
    };
    let mut big = Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    );
    for pr in &prompts {
        big.submit(pr.clone(), gp(false)).unwrap();
    }
    let mut want = big.run_until_idle().unwrap();
    want.sort_by_key(|r| r.id);

    // constrained + shared: 4 device groups, 8 host groups
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        device_kv_budget: 4 * group_bytes,
        host_kv_budget: 8 * group_bytes,
        page_size: 16,
        ..EngineConfig::default()
    };
    let mut e = Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    );
    for pr in &prompts {
        e.submit(pr.clone(), gp(true)).unwrap();
    }
    let mut out = e.run_until_idle().unwrap();
    out.sort_by_key(|r| r.id);

    assert_eq!(out.len(), want.len());
    for (a, b) in out.iter().zip(&want) {
        assert_eq!(
            a.tokens, b.tokens,
            "sharing + offload + preemption changed request {} tokens",
            a.id
        );
    }
    let m = &e.metrics;
    assert!(m.prefix_hits > 0, "the common prefix must have been shared");
    assert!(m.peak_pages_used <= 16, "device budget was 4 groups = 16 pages");
    assert_eq!(
        m.pages_used, m.shared_pages,
        "at idle only the prefix cache's retained runs stay resident"
    );
    assert_eq!(m.host_pages_used, 0, "host tier drained at idle");
}
