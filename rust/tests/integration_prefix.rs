//! Integration: cross-sequence shared-prefix KV pages + copy-on-write.
//!
//! Runs the full engine stack over [`HostModelBackend`] (no artifacts
//! needed) and pins the acceptance property of the prefix-sharing PR:
//! decode output with `share_prefix` on is **bit-identical** to the
//! unshared engine across random prefix lengths, page sizes, GQA
//! configs and thread counts; a copy-on-write split after divergence
//! never corrupts a sibling sequence; and sharing composes with the
//! tiered cache's migration/preemption machinery without changing
//! tokens.

use fastattn::attention::batch::ParallelConfig;
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout, PageCodec,
};
use fastattn::models::ModelShape;
use fastattn::prop_ensure;
use fastattn::proptest::check;

fn engine_for(model: ModelShape, max_seq: usize, page_size: usize, threads: usize) -> Engine {
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        page_size,
        ..EngineConfig::default()
    };
    Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::for_shape(model, max_seq))),
        cfg,
    )
}

/// Acceptance property: shared-prefix serving is token-identical to
/// unshared serving over random prefix lengths, page sizes, GQA
/// configs and thread counts, and the cases collectively exercise both
/// the block-chain hit path and the tail-block COW path.
#[test]
fn prop_shared_prefix_engine_parity() {
    let mut total_hits = 0u64;
    let mut total_cows = 0u64;
    let mut total_saved = 0u64;
    check(10, |rng| {
        let (heads, kvh) = *rng.pick(&[(2u32, 1u32), (4, 2), (4, 4), (6, 2)]);
        let model = ModelShape {
            name: "prefix-prop",
            params: 0,
            layers: rng.range(1, 3) as u32,
            heads,
            kv_heads: kvh,
            head_dim: *rng.pick(&[4u32, 8]),
            ffn: 32,
            vocab: 64,
        };
        let max_seq = 64;
        let page_size = rng.range(1, 9);
        let threads = rng.range(1, 5);
        let max_new = rng.range(2, 7);

        // prompts: a common "system" prefix + per-request suffixes,
        // plus one exact duplicate to exercise tail-block sharing
        let common = rng.range(2, 33);
        let system: Vec<i32> = (0..common).map(|_| rng.below(64) as i32).collect();
        let n = rng.range(2, 5);
        let mut prompts: Vec<Vec<i32>> = (0..n)
            .map(|i| {
                let mut p = system.clone();
                let extra = rng.range(0, 9);
                p.extend((0..extra).map(|t| ((t * 7 + i * 13) % 64) as i32));
                p
            })
            .collect();
        prompts.push(prompts[0].clone());

        let run = |share: bool| {
            let mut e = engine_for(model, max_seq, page_size, threads);
            for pr in &prompts {
                let gp = GenParams {
                    max_new_tokens: max_new,
                    eos_token: None,
                    share_prefix: share,
                };
                e.submit(pr.clone(), gp).unwrap();
            }
            let mut out = e.run_until_idle().unwrap();
            out.sort_by_key(|r| r.id);
            let toks: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
            (toks, e.metrics.clone())
        };
        let (base, bm) = run(false);
        let (shared, sm) = run(true);
        prop_ensure!(
            base == shared,
            "sharing changed tokens (heads={heads} kvh={kvh} layers={} \
             page_size={page_size} threads={threads} common={common})",
            model.layers
        );
        prop_ensure!(bm.prefix_hits == 0, "unshared engine must never hit");
        // at idle every sequence has released its pages; whatever is
        // still in use is exactly the prefix cache's retained runs
        prop_ensure!(
            sm.pages_used == sm.shared_pages,
            "sequence pages leaked: {} used at idle vs {} prefix-cache pages",
            sm.pages_used,
            sm.shared_pages
        );
        prop_ensure!(
            sm.prefilled_tokens + sm.prefix_tokens_saved == bm.prefilled_tokens,
            "saved tokens must exactly offset prefill work: {} + {} != {}",
            sm.prefilled_tokens,
            sm.prefix_tokens_saved,
            bm.prefilled_tokens
        );
        total_hits += sm.prefix_hits;
        total_cows += sm.cow_splits;
        total_saved += sm.prefix_tokens_saved;
        Ok(())
    });
    assert!(total_hits > 0, "no case ever hit the prefix cache");
    assert!(total_cows > 0, "no case ever exercised a COW split");
    assert!(total_saved > 0, "sharing never skipped any prefill work");
}

/// Sharing composes with the two-tier cache: a device-constrained
/// engine (cold blocks migrating to the host tier, preemption under
/// pressure, shared pages pinned on device) still generates exactly
/// the tokens of an unconstrained, unshared engine.
#[test]
fn sharing_survives_offload_and_preemption_pressure() {
    // tiny_gqa geometry: a block group is layers 2 × kv_heads 2 = 4
    // pages of 1 KiB each → 4 KiB per group.
    let group_bytes = 4 * 1024usize;
    let system = vec![11i32; 20];
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| {
            let mut p = system.clone();
            p.extend(vec![i as i32 + 30; 4]);
            p
        })
        .collect();
    let gp = |share: bool| GenParams {
        max_new_tokens: 16,
        eos_token: None,
        share_prefix: share,
    };

    // unconstrained, unshared reference
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        ..EngineConfig::default()
    };
    let mut big = Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    );
    for pr in &prompts {
        big.submit(pr.clone(), gp(false)).unwrap();
    }
    let mut want = big.run_until_idle().unwrap();
    want.sort_by_key(|r| r.id);

    // constrained + shared: 4 device groups, 8 host groups
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        device_kv_budget: 4 * group_bytes,
        host_kv_budget: 8 * group_bytes,
        page_size: 16,
        ..EngineConfig::default()
    };
    let mut e = Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    );
    for pr in &prompts {
        e.submit(pr.clone(), gp(true)).unwrap();
    }
    let mut out = e.run_until_idle().unwrap();
    out.sort_by_key(|r| r.id);

    assert_eq!(out.len(), want.len());
    for (a, b) in out.iter().zip(&want) {
        assert_eq!(
            a.tokens, b.tokens,
            "sharing + offload + preemption changed request {} tokens",
            a.id
        );
    }
    let m = &e.metrics;
    assert!(m.prefix_hits > 0, "the common prefix must have been shared");
    assert!(m.peak_pages_used <= 16, "device budget was 4 groups = 16 pages");
    assert_eq!(
        m.pages_used, m.shared_pages,
        "at idle only the prefix cache's retained runs stay resident"
    );
    assert_eq!(m.host_pages_used, 0, "host tier drained at idle");
}

/// Acceptance property of the cascade PR: with `EngineConfig::cascade`
/// on, decode over shared-prefix pages — one multi-query pass over the
/// shared tiles per adopter group, per-request suffix passes merged
/// through the kernel's LSE state — produces **bit-identical tokens**
/// to the per-sequence gather, across random prefix lengths × page
/// sizes {4, 16} × codecs {F32, Int8} × adopter counts {1..16} ×
/// threads {1, 4}; and the analytic gather accounting shrinks, never
/// grows.
#[test]
fn prop_cascade_engine_parity() {
    let mut total_passes = 0u64;
    let mut total_saved = 0u64;
    check(12, |rng| {
        let (heads, kvh) = *rng.pick(&[(2u32, 1u32), (4, 2), (4, 4)]);
        let model = ModelShape {
            name: "cascade-prop",
            params: 0,
            layers: rng.range(1, 3) as u32,
            heads,
            kv_heads: kvh,
            head_dim: *rng.pick(&[4u32, 8]),
            ffn: 32,
            vocab: 64,
        };
        let max_seq = 64;
        let page_size = *rng.pick(&[4usize, 16]);
        let threads = *rng.pick(&[1usize, 4]);
        let codec = if rng.bool() { PageCodec::Int8 } else { PageCodec::F32 };
        let adopters = rng.range(1, 17);
        let max_new = rng.range(2, 6);
        // a common "system" prefix spanning at least one whole page, so
        // the adopted chain blocks carry cascade-eligible KV tiles
        let common = rng.range(page_size, 33);
        let system: Vec<i32> = (0..common).map(|_| rng.below(64) as i32).collect();
        let prompts: Vec<Vec<i32>> = (0..adopters)
            .map(|i| {
                let mut p = system.clone();
                let extra = rng.range(0, 6);
                p.extend((0..extra).map(|t| ((t * 5 + i * 11) % 64) as i32));
                p
            })
            .collect();

        let run = |cascade: bool| {
            let cfg = EngineConfig {
                parallel: ParallelConfig { threads, min_work_per_thread: 0 },
                kv_layout: KvLayout::Paged,
                page_size,
                kv_codec: codec,
                cascade,
                ..EngineConfig::default()
            };
            // KV tiles sized to the page so shared runs always hold
            // whole tiles (the default 128-row tile exceeds max_seq)
            let host = HostModelConfig::for_shape(model, max_seq).with_block_kv(page_size);
            let mut e = Engine::with_backend(Box::new(HostModelBackend::new(host)), cfg);
            for pr in &prompts {
                let gp = GenParams {
                    max_new_tokens: max_new,
                    eos_token: None,
                    share_prefix: true,
                };
                e.submit(pr.clone(), gp).unwrap();
            }
            let mut out = e.run_until_idle().unwrap();
            out.sort_by_key(|r| r.id);
            let toks: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
            (toks, e.metrics.clone())
        };
        let (base, bm) = run(false);
        let (casc, cm) = run(true);
        prop_ensure!(
            base == casc,
            "cascade changed tokens (heads={heads} kvh={kvh} layers={} page_size={page_size} \
             codec={codec:?} adopters={adopters} threads={threads} common={common})",
            model.layers
        );
        prop_ensure!(
            bm.cascade_passes == 0 && bm.shared_rows_saved == 0,
            "cascade metrics must stay zero with the flag off"
        );
        prop_ensure!(
            cm.kv_bytes_gathered <= bm.kv_bytes_gathered,
            "cascade must never gather more: {} vs {}",
            cm.kv_bytes_gathered,
            bm.kv_bytes_gathered
        );
        prop_ensure!(
            (cm.shared_rows_saved > 0) == (cm.cascade_passes > 0),
            "saved rows without passes (or vice versa): {} passes, {} rows",
            cm.cascade_passes,
            cm.shared_rows_saved
        );
        total_passes += cm.cascade_passes;
        total_saved += cm.shared_rows_saved;
        Ok(())
    });
    assert!(total_passes > 0, "no case ever ran a cascade pass");
    assert!(total_saved > 0, "cascade never skipped any shared-row gather");
}

/// Cascade composes with the rest of the paged machinery: under device
/// pressure (offload, preemption, COW splits) the cascade engine still
/// generates exactly the tokens of an unconstrained non-cascade run,
/// and the gather accounting stays consistent.
#[test]
fn cascade_survives_offload_and_preemption_pressure() {
    let group_bytes = 4 * 1024usize;
    let system = vec![13i32; 20];
    let prompts: Vec<Vec<i32>> = (0..5)
        .map(|i| {
            let mut p = system.clone();
            p.extend(vec![i as i32 + 30; 3]);
            p
        })
        .collect();
    let gp = GenParams { max_new_tokens: 12, eos_token: None, share_prefix: true };

    // unconstrained reference, cascade off
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads: 2, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        page_size: 16,
        ..EngineConfig::default()
    };
    let host = HostModelConfig::tiny_gqa().with_block_kv(16);
    let mut big = Engine::with_backend(Box::new(HostModelBackend::new(host.clone())), cfg);
    for pr in &prompts {
        big.submit(pr.clone(), gp).unwrap();
    }
    let mut want = big.run_until_idle().unwrap();
    want.sort_by_key(|r| r.id);

    // constrained + cascade: 5 device groups, 8 host groups
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads: 2, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        device_kv_budget: 5 * group_bytes,
        host_kv_budget: 8 * group_bytes,
        page_size: 16,
        cascade: true,
        ..EngineConfig::default()
    };
    let mut e = Engine::with_backend(Box::new(HostModelBackend::new(host)), cfg);
    for pr in &prompts {
        e.submit(pr.clone(), gp).unwrap();
    }
    let mut out = e.run_until_idle().unwrap();
    out.sort_by_key(|r| r.id);

    assert_eq!(out.len(), want.len());
    for (a, b) in out.iter().zip(&want) {
        assert_eq!(
            a.tokens, b.tokens,
            "cascade + offload + preemption changed request {} tokens",
            a.id
        );
    }
    let m = &e.metrics;
    assert!(m.prefix_hits > 0, "the common prefix must have been shared");
    assert!(m.cascade_passes > 0, "shared tiles must have cascaded");
    assert!(m.shared_rows_saved > 0, "cascade saved no gather work");
}
