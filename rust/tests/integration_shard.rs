//! Integration: tensor-parallel sharded serving through the engine.
//!
//! Drives the full engine stack over [`ShardedBackend`] — N simulated
//! devices with the KV heads sharded across per-device page pools,
//! partial attention combined per tile through the real in-process
//! ring with modeled tiling-AllReduce timing — and pins the paper's
//! system property: **sharded decode is bit-identical to the
//! single-device engine, token for token**, across shard counts × GQA
//! configs × page sizes × thread counts, including under cross-shard
//! reclamation (cold-block migration and swap-out/resume mirrored on
//! every shard).
//!
//! Geometry used throughout: 8 KV heads (divisible by 1/2/4/8 shards),
//! head_dim 4, 2 layers, vocab 32, max_seq 64.

use fastattn::attention::batch::ParallelConfig;
use fastattn::coordinator::scheduler::Policy;
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout, PreemptMode,
    ShardedBackend, ShardedConfig,
};
use fastattn::models::ModelShape;

/// A GQA host model whose 8 KV heads split across 1, 2, 4 or 8 shards.
fn gqa_cfg(heads: u32, kv_heads: u32) -> HostModelConfig {
    HostModelConfig {
        model: ModelShape {
            name: "host-shard-it",
            params: 0,
            layers: 2,
            heads,
            kv_heads,
            head_dim: 4,
            ffn: 32,
            vocab: 32,
        },
        max_seq: 64,
        ..HostModelConfig::tiny_gqa()
    }
}

fn ecfg(threads: usize, page_size: usize) -> EngineConfig {
    EngineConfig {
        parallel: ParallelConfig { threads, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        page_size,
        ..EngineConfig::default()
    }
}

fn sharded_engine(cfg: &HostModelConfig, scfg: ShardedConfig, ec: EngineConfig) -> Engine {
    Engine::with_backend(Box::new(ShardedBackend::new(cfg.clone(), scfg).unwrap()), ec)
}

fn run(e: &mut Engine, prompts: &[Vec<i32>], p: GenParams) -> Vec<Vec<i32>> {
    for pr in prompts {
        e.submit(pr.clone(), p).unwrap();
    }
    let mut out = e.run_until_idle().unwrap();
    out.sort_by_key(|r| r.id);
    out.into_iter().map(|r| r.tokens).collect()
}

/// The acceptance property: the sharded engine is token-identical to
/// the single-device engine across shards {1,2,4,8} × GQA configs
/// (MHA and grouped) × page sizes × thread counts, and reports modeled
/// AllReduce time exactly when more than one shard exists.
#[test]
fn sharded_engine_token_identical_across_shards() {
    let p = GenParams { max_new_tokens: 8, eos_token: None, share_prefix: false };
    let prompts: Vec<Vec<i32>> = vec![
        (0..5).map(|t| (t * 7 + 3) % 32).collect(),
        (0..12).map(|t| (t * 3 + 1) % 32).collect(),
        (0..19).map(|t| (t * 11 + 5) % 32).collect(),
        (0..33).map(|t| (t * 5 + 2) % 32).collect(), // > the 32-token bucket: chunked
    ];
    for (heads, kvh) in [(8u32, 8u32), (16, 8)] {
        let cfg = gqa_cfg(heads, kvh);
        for page_size in [4usize, 16] {
            let mut base =
                Engine::with_backend(Box::new(HostModelBackend::new(cfg.clone())), ecfg(1, page_size));
            let want = run(&mut base, &prompts, p);
            assert!(want.iter().all(|t| t.len() == 8));
            assert_eq!(base.metrics.allreduce_modeled_s, 0.0);
            assert_eq!(base.metrics.allreduce_overlap_speedup(), 1.0);

            for shards in [1usize, 2, 4, 8] {
                for threads in [1usize, 2] {
                    let scfg =
                        ShardedConfig { tile_rows: 2, ..ShardedConfig::for_shards(shards) };
                    let mut e = sharded_engine(&cfg, scfg, ecfg(threads, page_size));
                    let got = run(&mut e, &prompts, p);
                    assert_eq!(
                        got, want,
                        "{shards} shards (h{heads}/kv{kvh}, page {page_size}, {threads} thr) \
                         diverged from single device"
                    );
                    let m = &e.metrics;
                    if shards > 1 {
                        assert!(m.allreduce_modeled_s > 0.0, "{shards} shards must charge comm");
                        assert!(m.allreduce_tiles > 0 && m.allreduce_bytes > 0);
                        assert!(
                            m.allreduce_serial_s >= m.allreduce_makespan_s - 1e-12,
                            "overlap can only help: serial {} < makespan {}",
                            m.allreduce_serial_s,
                            m.allreduce_makespan_s
                        );
                    } else {
                        assert_eq!(m.allreduce_modeled_s, 0.0, "one shard models no ring");
                        assert_eq!(m.allreduce_tiles, 0);
                    }
                    assert_eq!(m.pages_used, 0, "every shard pool drained at idle");
                    assert_eq!(m.host_pages_used, 0);
                }
            }
        }
    }
}

/// The combine schedule is timing-only: tiled vs serial AllReduce
/// produce identical tokens, and on batched decode (8 rows × tile_rows
/// 2 → 4 tiles per layer) the tiled schedule's modeled makespan
/// strictly beats the serial baseline, with hidden communication > 0.
#[test]
fn tiled_allreduce_beats_serial_in_engine_metrics() {
    let cfg = gqa_cfg(8, 8);
    let p = GenParams { max_new_tokens: 12, eos_token: None, share_prefix: false };
    let prompts: Vec<Vec<i32>> =
        (0..8).map(|i| (0..6).map(|t| (t * 3 + i as i32 + 1) % 32).collect()).collect();
    let run_mode = |overlap: bool| {
        let scfg = if overlap { ShardedConfig::for_shards(4) } else { ShardedConfig::serial(4) };
        let scfg = ShardedConfig { tile_rows: 2, ..scfg };
        // PrefillFirst: all 8 sequences admit before decode, so decode
        // steps batch 8 rows = 4 combine tiles per layer
        let ec = EngineConfig { policy: Policy::PrefillFirst, ..ecfg(1, 16) };
        let mut e = sharded_engine(&cfg, scfg, ec);
        let toks = run(&mut e, &prompts, p);
        (toks, e.metrics.clone())
    };
    let (serial_toks, sm) = run_mode(false);
    let (tiled_toks, om) = run_mode(true);
    assert_eq!(serial_toks, tiled_toks, "combine schedule must not change tokens");

    assert_eq!(sm.allreduce_hidden_s, 0.0, "serial combine hides nothing");
    assert_eq!(sm.allreduce_bytes, om.allreduce_bytes, "same activations combined");
    assert!(om.allreduce_hidden_s > 0.0, "tiling must hide comm under compute");
    assert!(om.allreduce_hidden_frac() > 0.0 && om.allreduce_hidden_frac() <= 1.0);
    assert!(
        om.allreduce_makespan_s < om.allreduce_serial_s,
        "tiled {} !< serial {}",
        om.allreduce_makespan_s,
        om.allreduce_serial_s
    );
    assert!(
        om.allreduce_overlap_speedup() > 1.0,
        "tiling-AllReduce speedup {} must exceed 1.0",
        om.allreduce_overlap_speedup()
    );
}

/// Engine over sharded KV with per-device budgets forced small: a
/// sequence outgrowing the device tier migrates its cold block group
/// on **every** shard (mirrored ladder), decode gathers across tiers
/// on every shard, and tokens still match the unconstrained
/// single-device run.
#[test]
fn cross_shard_migration_matches_unconstrained() {
    // 8 prompt + 40 generated = 48 tokens = 3 page-16 blocks; the
    // device tier holds 2 block groups per shard, so the third block
    // forces a cold-group migration on every shard.  A per-shard block
    // group is 2 layers × (8/shards) kv heads pages of 2·4·16·4 = 512 B.
    let p = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
    let prompt: Vec<i32> = (0..8).map(|t| (t * 7 + 2) % 32).collect();
    let cfg = gqa_cfg(8, 8);
    let mut base = Engine::with_backend(Box::new(HostModelBackend::new(cfg.clone())), ecfg(1, 16));
    base.submit(prompt.clone(), p).unwrap();
    let want = base.run_until_idle().unwrap();
    assert_eq!(base.metrics.pages_migrated, 0, "unconstrained run never migrates");

    for shards in [2usize, 4] {
        let group = 2 * (8 / shards); // per-shard pages per block group
        let group_bytes = group * 512;
        let ec = EngineConfig {
            device_kv_budget: 2 * group_bytes,
            host_kv_budget: 4 * group_bytes,
            ..ecfg(1, 16)
        };
        let scfg = ShardedConfig { tile_rows: 2, ..ShardedConfig::for_shards(shards) };
        let mut e = sharded_engine(&cfg, scfg, ec);
        e.submit(prompt.clone(), p).unwrap();
        let got = e.run_until_idle().unwrap();
        assert_eq!(got[0].tokens, want[0].tokens, "{shards}-shard offload changed tokens");
        let m = &e.metrics;
        assert!(
            m.pages_migrated >= (shards * group) as u64,
            "every shard must migrate its cold group, moved {} of {}",
            m.pages_migrated,
            shards * group
        );
        assert_eq!(m.migrated_bytes, m.pages_migrated * 512);
        assert_eq!(m.preemptions, 0, "migration covers a solo sequence");
        assert!(m.pcie_modeled_s > 0.0);
        assert!(m.allreduce_modeled_s > 0.0);
        assert_eq!(m.pages_used, 0, "device tiers drained at idle");
        assert_eq!(m.host_pages_used, 0, "host tiers drained at idle");
    }
}

/// Prefix sharing and cascade decode are single-shard features: on a
/// sharded engine (n_shards > 1) `share_prefix: true` requests must
/// degrade gracefully — tokens identical to the non-sharing run, every
/// sharing/cascade metric pinned to zero, no panic — rather than
/// silently corrupting the mirrored per-shard block tables.  Pins the
/// `paged && n_shards == 1` gate explicitly.
#[test]
fn sharded_share_prefix_degrades_gracefully() {
    let system: Vec<i32> = (0..20).map(|t| (t * 7 + 3) % 32).collect();
    let prompts: Vec<Vec<i32>> = (0..6)
        .map(|i| {
            let mut p = system.clone();
            p.extend((0..(i % 4)).map(|t| (t * 5 + i + 1) % 32));
            p
        })
        .collect();
    let cfg = gqa_cfg(8, 8);
    for shards in [2usize, 4] {
        let scfg = ShardedConfig { tile_rows: 2, ..ShardedConfig::for_shards(shards) };
        let run_sharded = |share: bool| {
            // cascade: true in the config must stay inert too — the
            // engine resolves the flag off when n_shards > 1
            let ec = EngineConfig { cascade: true, ..ecfg(2, 16) };
            let mut e = sharded_engine(&cfg, scfg, ec);
            let p = GenParams { max_new_tokens: 8, eos_token: None, share_prefix: share };
            let toks = run(&mut e, &prompts, p);
            (toks, e.metrics.clone())
        };
        let (plain_toks, pm) = run_sharded(false);
        let (shared_toks, sm) = run_sharded(true);
        assert_eq!(
            shared_toks, plain_toks,
            "{shards}-shard share_prefix run diverged from non-sharing run"
        );
        for (label, m) in [("plain", &pm), ("share_prefix", &sm)] {
            assert_eq!(m.prefix_hits, 0, "{shards} shards/{label}: sharing must stay off");
            assert_eq!(m.prefix_tokens_saved, 0, "{shards} shards/{label}");
            assert_eq!(m.shared_pages, 0, "{shards} shards/{label}");
            assert_eq!(m.cascade_passes, 0, "{shards} shards/{label}: cascade gated off");
            assert_eq!(m.shared_rows_saved, 0, "{shards} shards/{label}");
            assert_eq!(m.pages_used, 0, "{shards} shards/{label}: pools drained at idle");
        }
        assert!(sm.allreduce_modeled_s > 0.0, "{shards} shards still ran the ring");
    }
}

/// Swap-out preemption under sharding: the victim's block tables park
/// on the host tier of **every** shard in lockstep and resume together
/// with KV intact — no prompt token prefills twice on any shard — and
/// tokens match the unconstrained single-device run.
#[test]
fn cross_shard_swap_out_resumes_with_identical_tokens() {
    let p = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
    let prompts = [vec![1i32; 8], vec![2i32; 8]];
    let cfg = gqa_cfg(8, 8);
    let mut base = Engine::with_backend(Box::new(HostModelBackend::new(cfg.clone())), ecfg(1, 16));
    for pr in &prompts {
        base.submit(pr.clone(), p).unwrap();
    }
    let mut want = base.run_until_idle().unwrap();
    want.sort_by_key(|r| r.id);

    // two 48-token sequences over a 2+2-group cache per shard cannot
    // coexist; under Swap mode the youngest parks and resumes
    let group_bytes = 2 * (8 / 2) * 512;
    let ec = EngineConfig {
        device_kv_budget: 2 * group_bytes,
        host_kv_budget: 2 * group_bytes,
        preempt_mode: PreemptMode::Swap,
        ..ecfg(1, 16)
    };
    let scfg = ShardedConfig { tile_rows: 2, ..ShardedConfig::for_shards(2) };
    let mut e = sharded_engine(&cfg, scfg, ec);
    for pr in &prompts {
        e.submit(pr.clone(), p).unwrap();
    }
    let mut got = e.run_until_idle().unwrap();
    got.sort_by_key(|r| r.id);
    assert_eq!(got.len(), 2);
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.tokens, b.tokens, "cross-shard swap changed request {} tokens", a.id);
    }
    let m = &e.metrics;
    assert!(m.swaps_out >= 1, "the squeeze must swap the youngest out");
    assert_eq!(m.swaps_in, m.swaps_out, "every swap resumed");
    assert!(m.recompute_tokens_avoided > 0);
    assert_eq!(
        m.prefilled_tokens, 16,
        "swap-out preserves cached KV on every shard: no prompt token prefills twice"
    );
    assert!(m.allreduce_modeled_s > 0.0);
    assert_eq!(m.pages_used, 0, "device tiers drained at idle");
    assert_eq!(m.host_pages_used, 0, "host tiers drained at idle");
}
