//! Engine-level parity for the batched parallel decode path.
//!
//! Runs the full serving stack (batcher → scheduler → KV pack/unpack →
//! host-model backend → batched GQA decode attention) over a mixed
//! prefill/decode workload and asserts **token-for-token parity**
//! between the sequential (`threads = 1`) and parallel configurations.
//! No artifact bundle is needed: the host-model backend is a
//! deterministic pure-rust transformer, so equal seeds ⇒ equal models.

use fastattn::attention::batch::ParallelConfig;
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig,
};
use fastattn::models::TINY_GQA;

fn engine(threads: usize, cfg: HostModelConfig) -> Engine {
    let ecfg = EngineConfig {
        parallel: ParallelConfig { threads, min_work_per_thread: 0 },
        ..EngineConfig::default()
    };
    Engine::with_backend(Box::new(HostModelBackend::new(cfg)), ecfg)
}

/// The mixed workload: staggered submissions so prefill and decode steps
/// interleave (short prompts join while long sequences are mid-decode).
fn run_workload(threads: usize, cfg: HostModelConfig) -> Vec<(u64, Vec<i32>)> {
    let mut e = engine(threads, cfg);
    let mut ids = Vec::new();

    // wave 1: a burst of mixed-length prompts
    for i in 0..6usize {
        let len = 1 + (i * 5) % 14;
        let prompt: Vec<i32> = (0..len).map(|j| ((i * 37 + j * 11) % 300) as i32 + 1).collect();
        let gen = 2 + i % 5;
        ids.push(
            e.submit(prompt, GenParams { max_new_tokens: gen, ..GenParams::default() })
                .unwrap(),
        );
    }
    // let decoding start, then inject wave 2 mid-flight
    for _ in 0..4 {
        e.step().unwrap();
    }
    for i in 0..4usize {
        let prompt: Vec<i32> = (0..(3 + i * 7)).map(|j| (j * 13 + i) as i32 + 2).collect();
        ids.push(
            e.submit(prompt, GenParams { max_new_tokens: 6, eos_token: None, share_prefix: false })
                .unwrap(),
        );
    }
    // run_until_idle drains every finished response
    let out = e.run_until_idle().unwrap();
    assert_eq!(out.len(), ids.len(), "every request completes");
    let mut toks: Vec<(u64, Vec<i32>)> = out.into_iter().map(|r| (r.id, r.tokens)).collect();
    toks.sort_by_key(|(id, _)| *id);
    toks
}

#[test]
fn sequential_and_parallel_configs_agree_token_for_token() {
    let seq = run_workload(1, HostModelConfig::tiny_gqa());
    for threads in [2, 4] {
        let par = run_workload(threads, HostModelConfig::tiny_gqa());
        assert_eq!(seq, par, "threads={threads} changed generated tokens");
    }
}

#[test]
fn gqa_zoo_shape_serves_end_to_end() {
    // TINY_GQA: 4 query heads over 2 KV heads, D=64 — a real zoo shape
    // through the whole engine, sequential vs parallel.
    let cfg = || HostModelConfig::for_shape(TINY_GQA, 128);
    assert_eq!(cfg().model.kv_heads, 2);
    let seq = run_workload(1, cfg());
    let par = run_workload(4, cfg());
    assert_eq!(seq, par, "GQA zoo shape: parallel decode changed tokens");
    // sanity: tokens are in-vocab
    let vocab = TINY_GQA.vocab as i32;
    assert!(seq.iter().all(|(_, t)| t.iter().all(|&x| x >= 0 && x < vocab)));
}

#[test]
fn deterministic_across_runs_and_eos_respected() {
    let a = run_workload(2, HostModelConfig::tiny_gqa());
    let b = run_workload(2, HostModelConfig::tiny_gqa());
    assert_eq!(a, b, "same seed + same workload ⇒ same tokens");

    // learn the greedy continuation, then stop on its second token
    let mut e = engine(4, HostModelConfig::tiny_gqa());
    e.submit(vec![3, 1, 4, 1, 5], GenParams { max_new_tokens: 6, ..GenParams::default() })
        .unwrap();
    let full = e.run_until_idle().unwrap();
    let second = full[0].tokens[1];

    let mut e2 = engine(4, HostModelConfig::tiny_gqa());
    e2.submit(
        vec![3, 1, 4, 1, 5],
        GenParams { max_new_tokens: 6, eos_token: Some(second), share_prefix: false },
    )
    .unwrap();
    let stopped = e2.run_until_idle().unwrap();
    assert_eq!(stopped[0].tokens.len(), 2);
    assert_eq!(*stopped[0].tokens.last().unwrap(), second);
}
