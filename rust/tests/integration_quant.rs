//! Integration: int8 KV pages through the full serving stack.
//!
//! Runs the engine over [`HostModelBackend`] / [`ShardedBackend`] with
//! `EngineConfig::kv_codec = PageCodec::Int8` — rows quantize on append
//! (per-row scale side-channel) and dequantize fused inside the paged
//! attention gather — and pins the acceptance property: **quantized
//! serving produces exactly the f32 engine's greedy tokens** across
//! tiered offload, swap-out/resume preemption, shared-prefix
//! copy-on-write, and tensor-parallel sharding {1, 2, 4}.
//!
//! Budgets are sized in *block groups of the engine's own codec* so the
//! f32 and int8 runs see the same page-pressure dynamics: tiny_gqa is
//! layers 2 × kv_heads 2 = 4 pages per group; at page_size 16 /
//! head_dim 8 a page is 1 KiB (f32) or 384 B (int8).

use fastattn::attention::batch::ParallelConfig;
use fastattn::coordinator::kv_cache::kv_page_bytes_codec;
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout, PageCodec,
    PreemptMode, ShardedBackend, ShardedConfig,
};
use fastattn::models::ModelShape;

/// Bytes of one tiny_gqa block group (4 pages) at `codec`.
fn group_bytes(codec: PageCodec) -> usize {
    4 * kv_page_bytes_codec(16, 8, codec)
}

fn engine(codec: PageCodec, device_groups: usize, host_groups: usize, threads: usize) -> Engine {
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        device_kv_budget: device_groups * group_bytes(codec),
        host_kv_budget: host_groups * group_bytes(codec),
        page_size: 16,
        kv_codec: codec,
        ..EngineConfig::default()
    };
    Engine::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
    )
}

fn run(e: &mut Engine, prompts: &[Vec<i32>], p: GenParams) -> Vec<Vec<i32>> {
    for pr in prompts {
        e.submit(pr.clone(), p).unwrap();
    }
    let mut out = e.run_until_idle().unwrap();
    out.sort_by_key(|r| r.id);
    out.into_iter().map(|r| r.tokens).collect()
}

fn prompts() -> Vec<Vec<i32>> {
    (0..4)
        .map(|i| (0..(i * 7 + 9)).map(|t| ((t * 5 + i * 3 + 1) % 64) as i32).collect())
        .collect()
}

/// The base acceptance property: int8 pages serve exactly the f32
/// engine's greedy tokens (unconstrained, so codec is the only delta),
/// across thread counts, and the bandwidth counters record the ~4×
/// byte reduction exactly.
#[test]
fn int8_engine_matches_f32_tokens_and_counts_bytes() {
    let p = GenParams { max_new_tokens: 12, eos_token: None, share_prefix: false };
    let mut f = engine(PageCodec::F32, 1024, 0, 1);
    let want = run(&mut f, &prompts(), p);

    for threads in [1usize, 4] {
        let mut q = engine(PageCodec::Int8, 1024, 0, threads);
        let got = run(&mut q, &prompts(), p);
        assert_eq!(got, want, "int8 serving changed greedy tokens (threads={threads})");

        let (fm, qm) = (&f.metrics, &q.metrics);
        // identical tokens → identical gathered-row counts, so the
        // byte counters sit in the exact codec ratio: f32 rows are
        // 4·head_dim = 32 B, int8 rows head_dim + 4 = 12 B.
        assert!(qm.kv_bytes_gathered > 0 && fm.kv_bytes_gathered > 0);
        assert_eq!(
            qm.kv_bytes_gathered * 8,
            fm.kv_bytes_gathered * 3,
            "int8 gather bytes must be 12/32 of f32's"
        );
        assert!(qm.dequant_rows > 0, "int8 decode must count fused dequants");
        assert_eq!(fm.dequant_rows, 0, "f32 pools never dequantize");
    }
}

/// Tiered offload under device pressure: cold int8 pages migrate to the
/// host tier (compressed for free — 384 B each, not 1 KiB), decode
/// gathers across both tiers, tokens unchanged.
#[test]
fn int8_tiered_offload_matches_unconstrained() {
    // 60 prompt + 20 generated = 80 tokens = 5 block groups; a 3-group
    // device tier forces ≥ 2 groups to offload mid-flight.
    let prompt: Vec<i32> = (0..60).map(|i| (i * 3 + 1) % 64).collect();
    let p = GenParams { max_new_tokens: 20, eos_token: None, share_prefix: false };

    let mut base = engine(PageCodec::Int8, 1024, 0, 1);
    let want = run(&mut base, &[prompt.clone()], p);
    assert_eq!(base.metrics.pages_migrated, 0);

    // the f32 engine agrees before any pressure is applied
    let mut f = engine(PageCodec::F32, 1024, 0, 1);
    assert_eq!(run(&mut f, &[prompt.clone()], p), want);

    let mut tiered = engine(PageCodec::Int8, 3, 8, 1);
    let got = run(&mut tiered, &[prompt], p);
    assert_eq!(got, want, "int8 cold-page offload must not change greedy tokens");
    let m = &tiered.metrics;
    assert!(m.pages_migrated >= 8, "≥ 2 block groups must spill, migrated {}", m.pages_migrated);
    assert_eq!(
        m.migrated_bytes,
        m.pages_migrated * kv_page_bytes_codec(16, 8, PageCodec::Int8) as u64,
        "migration accounting must charge int8 page bytes"
    );
    assert!(m.pcie_modeled_s > 0.0);
    assert_eq!(m.pages_used, 0, "device tier drained at idle");
    assert_eq!(m.host_pages_used, 0, "host tier drained at idle");
}

/// Swap-out preemption and resume with quantized pages: the parked
/// block table round-trips through the host tier encoded, and every
/// request's tokens match its solo f32 run.
#[test]
fn int8_swap_resume_matches_f32() {
    // each request: 8 prompt + 40 generated = 48 tokens = 3 groups;
    // 3 live requests against device 2 + host 2 groups (the proven
    // squeeze of tests/integration_reclaim.rs) forces swap-outs.
    let p = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
    let prompts: Vec<Vec<i32>> = vec![vec![1; 8], vec![2; 8], vec![3; 8]];

    let mk = |codec| {
        let cfg = EngineConfig {
            parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
            kv_layout: KvLayout::Paged,
            device_kv_budget: 2 * group_bytes(codec),
            host_kv_budget: 2 * group_bytes(codec),
            page_size: 16,
            preempt_mode: PreemptMode::Swap,
            kv_codec: codec,
            ..EngineConfig::default()
        };
        Engine::with_backend(
            Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
            cfg,
        )
    };
    let mut q = mk(PageCodec::Int8);
    let got = run(&mut q, &prompts, p);
    assert!(q.metrics.swaps_out >= 1, "the squeeze must swap at least once");
    assert_eq!(q.metrics.swaps_in, q.metrics.swaps_out, "every parked table resumes");

    for (pr, got) in prompts.iter().zip(&got) {
        let mut solo = engine(PageCodec::F32, 1024, 0, 1);
        let want = run(&mut solo, &[pr.clone()], p);
        assert_eq!(&want[0], got, "swap/resume drifted from f32 for prompt {pr:?}");
    }
}

/// Shared-prefix pages with copy-on-write splits under the int8 codec:
/// adopting requests reuse quantized prefix pages, diverge via CoW, and
/// tokens match both the unshared int8 run and the f32 reference.
#[test]
fn int8_shared_prefix_cow_matches_f32() {
    let system = vec![7i32; 32];
    let prompts: Vec<Vec<i32>> = (0..6)
        .map(|i| {
            let mut pr = system.clone();
            pr.extend(vec![i as i32 + 40; 3]);
            pr
        })
        .collect();
    let run_with = |codec, share| {
        let mut e = engine(codec, 1024, 0, 1);
        let p = GenParams { max_new_tokens: 16, eos_token: None, share_prefix: share };
        let toks = run(&mut e, &prompts, p);
        (toks, e.metrics.clone())
    };
    let (f32_toks, _) = run_with(PageCodec::F32, false);
    let (unshared, _) = run_with(PageCodec::Int8, false);
    let (shared, sm) = run_with(PageCodec::Int8, true);
    assert_eq!(unshared, f32_toks, "int8 serving changed greedy tokens");
    assert_eq!(shared, f32_toks, "int8 prefix sharing changed greedy tokens");
    assert!(sm.prefix_hits > 0, "the common system prompt must hit");
    assert!(sm.prefix_tokens_saved > 0, "adopters must skip shared prefill");
}

/// Tensor-parallel shards {1, 2, 4} over per-shard int8 pools: token
/// streams identical to the single-device f32 engine.
#[test]
fn int8_sharded_engine_matches_f32_across_shards() {
    let host = HostModelConfig {
        model: ModelShape {
            name: "host-quant-it",
            params: 0,
            layers: 2,
            heads: 8,
            kv_heads: 8,
            head_dim: 4,
            ffn: 32,
            vocab: 32,
        },
        max_seq: 64,
        ..HostModelConfig::tiny_gqa()
    };
    let p = GenParams { max_new_tokens: 8, eos_token: None, share_prefix: false };
    let prompts: Vec<Vec<i32>> = vec![
        (0..5).map(|t| (t * 7 + 3) % 32).collect(),
        (0..12).map(|t| (t * 3 + 1) % 32).collect(),
        (0..19).map(|t| (t * 11 + 5) % 32).collect(),
    ];
    let ecfg = |codec| EngineConfig {
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        page_size: 16,
        kv_codec: codec,
        ..EngineConfig::default()
    };
    let mut f = Engine::with_backend(
        Box::new(HostModelBackend::new(host.clone())),
        ecfg(PageCodec::F32),
    );
    let want = run(&mut f, &prompts, p);
    for shards in [1usize, 2, 4] {
        let mut e = Engine::with_backend(
            Box::new(ShardedBackend::new(host.clone(), ShardedConfig::for_shards(shards)).unwrap()),
            ecfg(PageCodec::Int8),
        );
        let got = run(&mut e, &prompts, p);
        assert_eq!(got, want, "int8 sharded serving drifted at {shards} shards");
        assert!(e.metrics.dequant_rows > 0, "sharded decode must hit the int8 gather");
    }
}
