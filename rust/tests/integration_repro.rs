//! Reproduction-claims integration tests: every paper table/figure
//! regenerates, and the headline directional claims hold in the models.

use fastattn::models;
use fastattn::reports;
use fastattn::sim::ascend::{AscendSpec, FastAttnOptions, Tiling};
use fastattn::sim::collective::{best_block_count, make_blocks, serial_schedule, RingSpec};
use fastattn::sim::memory::Deployment;
use fastattn::sim::volta::{VoltaKernel, VoltaSpec};
use fastattn::sim::AttnWorkload;

#[test]
fn every_experiment_regenerates() {
    for id in reports::ALL {
        let t = reports::by_id(id).unwrap_or_else(|| panic!("missing {id}"));
        t.print();
    }
}

#[test]
fn headline_fig7_band() {
    // "FastAttention is 4.85–10.7× faster than standard attention on an
    // Ascend NPU" — allow a ±35% calibration margin on each end.
    let spec = AscendSpec::default();
    let opts = FastAttnOptions::default();
    let mut speedups = Vec::new();
    for s in [1024u64, 2048, 4096, 8192, 16384] {
        let w = AttnWorkload::prefill(1, 5, s, 128, true);
        let sp = spec.standard_attention_latency(&w)
            / spec.fastattn_latency(&w, &opts).latency_s;
        speedups.push(sp);
    }
    let lo = speedups.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = speedups.iter().cloned().fold(0.0, f64::max);
    assert!(lo > 3.2 && lo < 7.0, "low end {lo:.2} (paper 4.85)");
    assert!(hi > 7.0 && hi < 14.0, "high end {hi:.2} (paper 10.7)");
    // monotone in S (the paper's curves grow with sequence length)
    for w in speedups.windows(2) {
        assert!(w[1] >= w[0] * 0.95, "speedup collapsed: {speedups:?}");
    }
}

#[test]
fn headline_fig8_band() {
    // "1.43× speedup compared to its equivalents in xformers" (causal,
    // 16K); non-causal 1.03–1.17 monotone.
    let spec = VoltaSpec::default();
    let mut prev = 0.0;
    for s in [2048u64, 4096, 8192, 16384] {
        let w = AttnWorkload::prefill(8, 64, s, 32, false);
        let sp = spec.attention_latency(VoltaKernel::Xformers, &w)
            / spec.attention_latency(VoltaKernel::FastAttention, &w);
        assert!(sp >= prev && sp < 1.35, "S={s}: {sp:.2}");
        prev = sp;
    }
    let w = AttnWorkload::prefill(8, 64, 16384, 32, true);
    let sp = spec.attention_latency(VoltaKernel::Xformers, &w)
        / spec.attention_latency(VoltaKernel::FastAttention, &w);
    assert!(sp > 1.28 && sp < 1.6, "causal 16K: {sp:.2} (paper 1.43)");
}

#[test]
fn headline_context_extension() {
    // "supports a maximal input length of 256K on 8 V100 GPUs" vs 16K.
    let dep = Deployment::v100_node(models::PANGU_38B, 0, 50);
    let base = dep.max_seq_without_offload();
    let coop = dep.max_seq_with_offload(768 << 30);
    assert!(base < 32 * 1024, "baseline {base}");
    assert!(coop >= 256 * 1024, "coop {coop}");
    assert!(coop / base.max(1) >= 8, "extension factor");
}

#[test]
fn headline_two_level_vs_unified() {
    // Table 2 ordering: two-level strictly dominates unified at every S.
    let spec = AscendSpec::default();
    for s in [1024u64, 4096, 16384] {
        let w = AttnWorkload::prefill(1, 5, s, 128, true);
        let uni = spec
            .fastattn_latency(
                &w,
                &FastAttnOptions { tiling: Tiling::Unified { block: 128 }, ..Default::default() },
            )
            .latency_s;
        let two = spec.fastattn_latency(&w, &FastAttnOptions::default()).latency_s;
        assert!(two < uni, "S={s}");
    }
}

#[test]
fn headline_allreduce_overlap_band() {
    // Fig 16/17: tiling-AllReduce gains 1.2–1.7× on the layer total.
    let ring = RingSpec::default();
    let spec = AscendSpec::default();
    for s in [8192u64, 32768] {
        let w = AttnWorkload::prefill(1, 5, s, 128, true);
        let compute = spec.fastattn_latency(&w, &FastAttnOptions::default()).latency_s
            + spec.linear_latency(s, 5120, 20480, 8, 2, true);
        let bytes = 2 * s * 5120;
        let serial = serial_schedule(&ring, &make_blocks(bytes, compute, 1, 1.0));
        let (nb, over) = best_block_count(&ring, bytes, compute);
        let sp = serial / over;
        assert!(nb >= 2, "S={s}: no tiling chosen");
        assert!(sp > 1.1 && sp < 1.9, "S={s}: {sp:.2}");
    }
}

#[test]
fn tiling_mask_memory_claim() {
    // 8 GB full mask at 64K vs sub-MB M-mask (paper §4.1).
    use fastattn::attention::mask::MMask;
    let mm = MMask::new(512);
    let full = 64u64 * 1024 * 64 * 1024 * 2;
    assert_eq!(full, 8 << 30);
    assert!(mm.bytes() < (4 << 20));
}
