//! Integration: the paged KV cache + chunked prefill serving path.
//!
//! Runs the full engine stack over [`HostModelBackend`] (no artifacts
//! needed): long prompts beyond every prefill bucket complete through
//! chunked prefill; the paged layout is token-identical to the
//! contiguous layout; pool exhaustion preempts instead of panicking and
//! preempted requests still finish with identical tokens; page
//! occupancy is reported through `EngineMetrics`.

use fastattn::attention::batch::ParallelConfig;
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout,
};

fn engine_with(cfg: HostModelConfig, ecfg: EngineConfig) -> Engine {
    Engine::with_backend(Box::new(HostModelBackend::new(cfg)), ecfg)
}

fn seq_cfg(layout: KvLayout) -> EngineConfig {
    EngineConfig {
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: layout,
        ..EngineConfig::default()
    }
}

/// A prompt longer than the largest prefill bucket (32 for `tiny_gqa`)
/// completes end-to-end through chunked prefill, and its tokens are
/// bit-identical to the same model served contiguously through a
/// large-enough bucket.
#[test]
fn long_prompt_completes_via_chunked_prefill() {
    let prompt: Vec<i32> = (0..50).map(|i| (i * 3 + 1) % 64).collect();
    let p = GenParams { max_new_tokens: 6, eos_token: None, share_prefix: false };

    // paged engine with the stock small buckets: must chunk
    let mut paged = engine_with(HostModelConfig::tiny_gqa(), seq_cfg(KvLayout::Paged));
    let id = paged.submit(prompt.clone(), p).unwrap();
    let out = paged.run_until_idle().unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].id, id);
    assert_eq!(out[0].prompt_len, 50);
    assert_eq!(out[0].tokens.len(), 6);
    assert!(
        paged.metrics.chunk_steps >= 2,
        "50 tokens over 32-token chunks need at least 2 chunk steps, got {}",
        paged.metrics.chunk_steps
    );
    assert_eq!(paged.metrics.prefilled_tokens, 50);
    assert!(paged.metrics.pages_total > 0);
    assert_eq!(paged.metrics.pages_used, 0, "pages released at idle");
    assert!(paged.metrics.peak_pages_used > 0);

    // same model (same seed) with a 64 bucket, contiguous layout: the
    // unchunked reference
    let mut big = HostModelConfig::tiny_gqa();
    big.buckets.prefill_seqs = vec![8, 16, 32, 64];
    let mut contig = engine_with(big, seq_cfg(KvLayout::Contiguous));
    contig.submit(prompt.clone(), p).unwrap();
    let want = contig.run_until_idle().unwrap();
    assert_eq!(
        out[0].tokens, want[0].tokens,
        "chunked paged serving must not change greedy tokens"
    );

    // the contiguous engine with stock buckets rejects the same prompt
    let mut small = engine_with(HostModelConfig::tiny_gqa(), seq_cfg(KvLayout::Contiguous));
    assert!(small.submit(prompt, p).is_err());
}

/// Mixed workload parity: paged vs contiguous layouts generate
/// identical tokens for every request, across thread counts.
#[test]
fn paged_vs_contiguous_under_load() {
    let p = GenParams { max_new_tokens: 7, eos_token: None, share_prefix: false };
    let prompts: Vec<Vec<i32>> = (0..9)
        .map(|i| (0..(i * 5 + 2) % 30 + 1).map(|t| ((t * 7 + i) % 64) as i32).collect())
        .collect();
    let run = |layout: KvLayout, threads: usize| {
        let cfg = EngineConfig {
            parallel: ParallelConfig { threads, min_work_per_thread: 0 },
            kv_layout: layout,
            ..EngineConfig::default()
        };
        let mut e = engine_with(HostModelConfig::tiny_gqa(), cfg);
        for pr in &prompts {
            e.submit(pr.clone(), p).unwrap();
        }
        let mut out = e.run_until_idle().unwrap();
        out.sort_by_key(|r| r.id);
        out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };
    let contig = run(KvLayout::Contiguous, 1);
    assert_eq!(contig, run(KvLayout::Paged, 1), "layouts diverge (threads=1)");
    assert_eq!(contig, run(KvLayout::Paged, 4), "layouts diverge (threads=4)");
}

/// A page pool too small for two full sequences: the engine preempts
/// the youngest (recompute-style) instead of panicking, both requests
/// still complete, and their tokens match unconstrained solo runs.
#[test]
fn pool_exhaustion_preempts_youngest_and_recovers() {
    // tiny_gqa: layers 2 × kv_heads 2 → 4 pages per 16-token block.
    // Each request spans 8 prompt + 24 generated = 32 tokens = 8 pages;
    // a 12-page pool fits one full sequence plus half of another.
    let p = GenParams { max_new_tokens: 24, eos_token: None, share_prefix: false };
    let prompts: Vec<Vec<i32>> = vec![vec![1; 8], vec![2; 8]];
    let cfg = EngineConfig {
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        device_kv_budget: 12 * 1024, // 12 pages at page_size 16, head_dim 8
        page_size: 16,
        ..EngineConfig::default()
    };
    let mut e = engine_with(HostModelConfig::tiny_gqa(), cfg);
    assert!(e.is_paged());
    for pr in &prompts {
        e.submit(pr.clone(), p).unwrap();
    }
    let mut out = e.run_until_idle().unwrap();
    out.sort_by_key(|r| r.id);
    assert_eq!(out.len(), 2, "both requests complete despite preemption");
    assert!(out.iter().all(|r| r.tokens.len() == 24));
    assert!(
        e.metrics.preemptions >= 1,
        "the overcommitted pool must have preempted (preemptions = {})",
        e.metrics.preemptions
    );
    assert!(e.metrics.alloc_failures >= 1);
    assert_eq!(e.metrics.pages_used, 0, "all pages released at idle");
    assert!(e.metrics.peak_pages_used <= 12);

    // preemption + recompute must not change any request's tokens
    for (pr, got) in prompts.iter().zip(&out) {
        let mut solo = engine_with(HostModelConfig::tiny_gqa(), seq_cfg(KvLayout::Paged));
        solo.submit(pr.clone(), p).unwrap();
        let want = solo.run_until_idle().unwrap();
        assert_eq!(want[0].tokens, got.tokens, "prompt {pr:?}");
    }
}

/// Requests too large for the whole pool are refused up front (typed
/// admission), not admitted and then starved.
#[test]
fn impossible_requests_refused_up_front() {
    let cfg = EngineConfig {
        kv_layout: KvLayout::Paged,
        device_kv_budget: 4 * 1024, // 4 pages → one 16-token block
        page_size: 16,
        ..EngineConfig::default()
    };
    let mut e = engine_with(HostModelConfig::tiny_gqa(), cfg);
    // 8 + 16 = 24 tokens → 2 blocks → 8 pages > 4 in the pool
    let p16 = GenParams { max_new_tokens: 16, ..GenParams::default() };
    assert!(e.submit(vec![1; 8], p16).is_err());
    // empty prompts and over-max_seq prompts stay refused too
    assert!(e.submit(vec![], GenParams::default()).is_err());
    assert!(e
        .submit(vec![1; 90], GenParams { max_new_tokens: 20, eos_token: None, share_prefix: false })
        .is_err());
    // a request that fits the pool is accepted and completes
    let id = e
        .submit(vec![1; 8], GenParams { max_new_tokens: 8, eos_token: None, share_prefix: false })
        .unwrap();
    let out = e.run_until_idle().unwrap();
    assert_eq!(out[0].id, id);
    assert_eq!(out[0].tokens.len(), 8);
}

/// Page occupancy is visible mid-flight through `EngineMetrics`.
#[test]
fn occupancy_visible_during_decode() {
    let mut e = engine_with(HostModelConfig::tiny_gqa(), seq_cfg(KvLayout::Paged));
    e.submit(vec![5; 12], GenParams { max_new_tokens: 10, eos_token: None, share_prefix: false })
        .unwrap();
    // first step admits + chunk-prefills: pages must be in use
    e.step().unwrap();
    assert!(e.metrics.pages_used > 0, "occupancy after prefill chunk");
    assert!(e.metrics.page_occupancy() > 0.0);
    assert!(e.metrics.page_occupancy() <= 1.0);
    let during = e.metrics.pages_used;
    let out = e.run_until_idle().unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(e.metrics.pages_used, 0);
    assert!(e.metrics.peak_pages_used >= during);
}
