//! Regenerates the paper experiment — see fastattn::reports for the
//! workload, parameters, and paper-vs-measured comparison logic.
fn main() {
    fastattn::reports::volta::table5_deepspeed().print();
}
