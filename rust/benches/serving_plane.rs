//! Open-loop serving benchmark for the continuous-batching request
//! plane: Poisson arrivals (deterministic LCG, no external RNG) with
//! mixed prompt/generation lengths are pushed through the threaded
//! [`Server`] front-end over the host model, with one collector thread
//! per request consuming its token stream.
//!
//! Two admission policies serve the identical trace:
//!
//!   * **token-budget** — the default continuous-batching plane
//!     (`max_batch_prefill_tokens = 0` → one `max_chunk` of packed
//!     chunk rows per prefill step);
//!   * **bucket** — `max_batch_prefill_tokens = 1`, which degenerates
//!     to the old one-sequence-per-prefill-step bucket admission.
//!
//! For each policy the bench reports goodput-under-SLO: generated
//! tok/s counting only requests whose TTFT and TPOT met the target,
//! over a grid of SLO targets from strict to unbounded.  Rows land in
//! `BENCH_serving.json`.  Streamed-vs-final token parity is asserted
//! for every request of every run — the bench doubles as an end-to-end
//! check of the streaming no-hang contract under concurrency.
//!
//! `FASTATTN_SMOKE=1` (and debug builds) shrink the trace for CI.

use std::time::Duration;

use fastattn::benchkit::{rate, write_bench_json, Table};
use fastattn::coordinator::{
    EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout, Response, Server,
    ServerConfig, StreamEvent,
};

/// Deterministic 64-bit LCG (`Date`-free, seed-stable across runs).
struct Lcg(u64);

impl Lcg {
    fn next_u64(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0
    }

    /// Uniform in (0, 1].
    fn uniform(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 1.0) / (1u64 << 53) as f64
    }

    /// Exponential inter-arrival with the given mean (Poisson process).
    fn exp(&mut self, mean_s: f64) -> f64 {
        -self.uniform().ln() * mean_s
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next_u64() % (hi - lo + 1) as u64) as usize
    }
}

/// One request of the open-loop trace.
struct Arrival {
    delay: Duration,
    prompt: Vec<i32>,
    gen: usize,
}

/// The Poisson trace: same seed → same arrivals for every policy.
fn trace(n: usize, mean_interarrival_s: f64) -> Vec<Arrival> {
    let mut rng = Lcg(0x5eed_5e12_11a6);
    (0..n)
        .map(|_| {
            let delay = Duration::from_secs_f64(rng.exp(mean_interarrival_s));
            let len = rng.range(4, 40);
            let prompt: Vec<i32> = (0..len).map(|_| rng.range(1, 60) as i32).collect();
            let gen = rng.range(4, 16);
            Arrival { delay, prompt, gen }
        })
        .collect()
}

/// Serve the trace through a fresh threaded server; returns the
/// completed responses and the wall-clock seconds of the whole run.
/// Panics if any stream hangs, errors, or diverges from its final
/// response — the parity/no-hang gate.
fn serve_trace(arrivals: &[Arrival], prefill_budget: usize) -> (Vec<Response>, f64) {
    let cfg = EngineConfig {
        kv_layout: KvLayout::Paged,
        max_batch_prefill_tokens: prefill_budget,
        ..EngineConfig::default()
    };
    let server = Server::with_backend(
        Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
        cfg,
        ServerConfig::default(),
    );
    let t0 = std::time::Instant::now();
    let mut collectors = Vec::new();
    for a in arrivals {
        // open loop: arrivals do not wait for service
        std::thread::sleep(a.delay);
        let stream = server
            .submit(
                a.prompt.clone(),
                GenParams { max_new_tokens: a.gen, eos_token: None, share_prefix: false },
            )
            .expect("trace request admitted");
        collectors.push(std::thread::spawn(move || {
            let mut streamed = Vec::new();
            loop {
                match stream.recv_timeout(Duration::from_secs(120)) {
                    Some(StreamEvent::Token { index, token }) => {
                        assert_eq!(index, streamed.len(), "stream skipped an index");
                        streamed.push(token);
                    }
                    Some(StreamEvent::Done(resp)) => {
                        assert_eq!(
                            streamed, resp.tokens,
                            "streamed tokens diverged from the final response"
                        );
                        return resp;
                    }
                    Some(StreamEvent::Error(e)) => panic!("typed error mid-bench: {e}"),
                    None => panic!("stream hung — no-hang contract broken"),
                }
            }
        }));
    }
    let responses: Vec<Response> =
        collectors.into_iter().map(|c| c.join().expect("collector panicked")).collect();
    (responses, t0.elapsed().as_secs_f64())
}

/// Generated tok/s counting only requests that met both SLO targets.
fn goodput(responses: &[Response], wall_s: f64, ttft_slo_s: f64, tpot_slo_s: f64) -> f64 {
    let good: usize = responses
        .iter()
        .filter(|r| {
            let tpot = if r.tokens.len() > 1 {
                (r.total_s - r.ttft_s) / (r.tokens.len() - 1) as f64
            } else {
                0.0
            };
            r.ttft_s <= ttft_slo_s && tpot <= tpot_slo_s
        })
        .map(|r| r.tokens.len())
        .sum();
    good as f64 / wall_s.max(1e-12)
}

fn main() {
    let smoke = std::env::var("FASTATTN_SMOKE").is_ok() || cfg!(debug_assertions);
    let (n, mean_gap_s) = if smoke { (16, 0.4e-3) } else { (64, 0.4e-3) };
    let arrivals = trace(n, mean_gap_s);
    let total_prompt: usize = arrivals.iter().map(|a| a.prompt.len()).sum();
    let total_gen: usize = arrivals.iter().map(|a| a.gen).sum();
    println!(
        "open-loop trace: {n} requests, Poisson mean gap {:.1} µs, \
         {total_prompt} prompt + {total_gen} generated tokens",
        mean_gap_s * 1e6
    );

    // (label, ttft SLO, tpot SLO) — strict to unbounded
    let slos: &[(&str, f64, f64)] = &[
        ("strict  ttft≤2ms tpot≤200µs", 2e-3, 200e-6),
        ("medium  ttft≤10ms tpot≤1ms", 10e-3, 1e-3),
        ("loose   ttft≤100ms tpot≤10ms", 100e-3, 10e-3),
        ("unbounded", f64::INFINITY, f64::INFINITY),
    ];

    let mut table = Table::new(
        "open-loop serving: goodput under SLO (generated tok/s of SLO-meeting requests)",
        &["admission", "SLO target", "goodput", "met"],
    );
    let mut rows: Vec<(String, f64)> = Vec::new();
    let mut by_policy: Vec<(&str, Vec<f64>)> = Vec::new();
    for (policy, budget) in [("token-budget", 0usize), ("bucket", 1usize)] {
        let (responses, wall_s) = serve_trace(&arrivals, budget);
        assert_eq!(responses.len(), n, "{policy}: every request must complete");
        let mut curve = Vec::new();
        for &(label, ttft, tpot) in slos {
            let g = goodput(&responses, wall_s, ttft, tpot);
            let met = responses
                .iter()
                .filter(|r| {
                    let tpot_r = if r.tokens.len() > 1 {
                        (r.total_s - r.ttft_s) / (r.tokens.len() - 1) as f64
                    } else {
                        0.0
                    };
                    r.ttft_s <= ttft && tpot_r <= tpot
                })
                .count();
            table.row(&[
                policy.into(),
                label.into(),
                rate(g * wall_s.max(1e-12), wall_s.max(1e-12), "tok"),
                format!("{met}/{n}"),
            ]);
            rows.push((format!("{policy} {label}"), g));
            curve.push(g);
        }
        by_policy.push((policy, curve));
    }
    table.print();

    // Packed token-budget admission must not lose to bucket admission
    // where the SLO cannot mask scheduling noise (the unbounded row
    // counts every completed token).  A small tolerance absorbs
    // wall-clock jitter of the tiny-model runs.
    let tb = by_policy[0].1.last().copied().unwrap_or(0.0);
    let bucket = by_policy[1].1.last().copied().unwrap_or(0.0);
    assert!(
        tb >= bucket * 0.7,
        "token-budget goodput ({tb:.0} tok/s) fell far below bucket admission ({bucket:.0} tok/s)"
    );

    let path = std::path::Path::new("BENCH_serving.json");
    match write_bench_json(path, "serving", "goodput tok/s", &rows) {
        Ok(()) => println!("\nwrote {} ({} rows)", path.display(), rows.len()),
        Err(e) => eprintln!("\nBENCH_serving.json not written: {e}"),
    }
}
