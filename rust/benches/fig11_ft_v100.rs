//! Regenerates the paper experiment — see fastattn::reports for the
//! workload, parameters, and paper-vs-measured comparison logic.
fn main() {
    fastattn::reports::volta::fig11_ft_v100().print();
}
