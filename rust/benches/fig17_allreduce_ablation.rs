//! Regenerates the paper experiment — see fastattn::reports for the
//! workload, parameters, and paper-vs-measured comparison logic —
//! then runs the multi-device serving sweep (sharded engine + paper
//! shapes, token parity asserted) and writes `BENCH_multi.json`.
fn main() {
    fastattn::reports::allreduce::fig17_ablation().print();
    println!();
    fastattn::reports::multi::multi_table().print();
    let path = std::path::Path::new("BENCH_multi.json");
    match fastattn::reports::multi::write_bench_multi(path) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nBENCH_multi.json not written: {e}"),
    }
}
