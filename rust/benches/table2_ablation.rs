//! Regenerates the paper experiment — see fastattn::reports for the
//! workload, parameters, and paper-vs-measured comparison logic.
fn main() {
    fastattn::reports::npu::table2_ablation().print();
}
