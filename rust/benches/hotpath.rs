//! Hot-path microbenchmarks — the perf-pass instrument (EXPERIMENTS.md
//! §Perf).  Measures the real execution-layer costs:
//!
//!   * PJRT artifact execution (standalone kernel, prefill, decode)
//!   * engine decode step end-to-end (pack → execute → unpack → sample)
//!   * batched parallel decode attention (GQA), single-thread vs
//!     parallel vs **paged** (block-table gather): per-batch latency,
//!     decode tok/s, speedup
//!   * paged-gather throughput: per-row scalar gather vs blocked
//!     page-run walking vs int8 pages with fused dequantization
//!     (f32 bit-identity and int8 tolerance asserted)
//!   * the host-model engine end-to-end (no artifacts needed)
//!   * tiered paged KV: device-only vs cold-page host offload at
//!     several modeled device capacities (token-parity asserted)
//!   * KV reclamation: swap-out vs recompute preemption of the same
//!     over-committed workload at two modeled device capacities
//!     (token-parity asserted)
//!   * shared-prefix KV pages: N requests × one system prompt, served
//!     with `share_prefix` off vs on (token-parity asserted)
//!   * KV-cache batch pack/unpack memcpy
//!   * the rust CPU FlashAttention2 kernel (offload host path)
//!   * the threaded ring AllReduce
//!
//! Run with `cargo bench --bench hotpath` (release profile).  Decode
//! throughput rows are additionally written to `BENCH_decode.json`, the
//! device-only-vs-tiered rows to `BENCH_offload.json`, the
//! shared-vs-unshared prefix rows to `BENCH_prefix.json`, and the
//! swap-vs-recompute preemption rows to `BENCH_reclaim.json`, in the
//! invocation directory, so the perf trajectory is machine-readable
//! across PRs.

use fastattn::attention::batch::{
    batch_decode_attention, BatchShape, ParallelConfig, SeqAttn, SeqKv, WorkPool,
};
use fastattn::attention::flash::{
    flash_attention, flash_attention_view, flash_attention_view_rowwise, FlashParams, KvView,
};
use fastattn::benchkit::{bench, fmt_time, rate, write_bench_json, x, Table};
use fastattn::coordinator::allreduce::ring_all_reduce;
use fastattn::coordinator::kv_cache::{pack_batch, BlockTable, CacheShape, PageCodec, PagePool};
use fastattn::coordinator::scheduler::Policy;
use fastattn::coordinator::{
    BucketGrid, Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout,
    PreemptMode, VictimPolicy,
};
use fastattn::models::{ModelShape, MISTRAL_7B, TINY_GQA};
use fastattn::proptest::Rng;
use fastattn::runtime::{HostTensor, Runtime};

/// One synthetic decode batch over a model shape: `nseq` sequences at
/// `kv` cached tokens each.
struct DecodeBatchData {
    shape: BatchShape,
    q: Vec<Vec<f32>>,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    kv: usize,
}

impl DecodeBatchData {
    fn synth(m: &ModelShape, nseq: usize, kv: usize) -> Self {
        let (h, kvh, d) = (m.heads as usize, m.kv_heads as usize, m.head_dim as usize);
        let shape = BatchShape::new(h, kvh, d, kv);
        let mut rng = Rng::new(nseq as u64 * 31 + kv as u64);
        Self {
            shape,
            q: (0..nseq).map(|_| rng.f32_vec(h * d)).collect(),
            k: (0..nseq).map(|_| rng.f32_vec(kvh * kv * d)).collect(),
            v: (0..nseq).map(|_| rng.f32_vec(kvh * kv * d)).collect(),
            kv,
        }
    }

    fn seqs(&self) -> Vec<SeqAttn<'_>> {
        (0..self.q.len())
            .map(|i| SeqAttn::contig(&self.q[i], &self.k[i], &self.v[i], self.kv))
            .collect()
    }

    /// Scatter the same rows into a paged pool (single-layer cache
    /// geometry) so the paged gather can be benched on identical data.
    fn paged(&self, page_size: usize) -> (PagePool, Vec<BlockTable>) {
        let (kvh, d, stride) =
            (self.shape.kv_heads, self.shape.head_dim, self.shape.kv_stride);
        let cache = CacheShape { layers: 1, kv_heads: kvh, max_seq: stride, head_dim: d };
        let per_seq = BlockTable::pages_needed(cache, page_size, stride);
        let mut pool = PagePool::new(page_size, d, per_seq * self.q.len());
        let mut tables = Vec::new();
        for i in 0..self.q.len() {
            let mut t = BlockTable::new(cache, page_size);
            t.ensure_capacity(self.kv, &mut pool).expect("pool sized for batch");
            for g in 0..kvh {
                for r in 0..self.kv {
                    let (page, slot) = t.locate(0, g, r);
                    let src = g * self.kv * d + r * d;
                    pool.write_row(
                        page,
                        slot,
                        &self.k[i][src..src + d],
                        &self.v[i][src..src + d],
                    );
                }
            }
            tables.push(t);
        }
        (pool, tables)
    }

    fn paged_seqs<'a>(&'a self, pool: &'a PagePool, tables: &'a [BlockTable]) -> Vec<SeqAttn<'a>> {
        (0..self.q.len())
            .map(|i| SeqAttn {
                q: &self.q[i],
                kv: SeqKv::Paged {
                    k_store: pool.k_store(),
                    v_store: pool.v_store(),
                    pages: tables[i].layer_pages(0),
                    max_blocks: tables[i].max_blocks(),
                    page_size: pool.page_size(),
                },
                kv_len: self.kv,
            })
            .collect()
    }
}

fn main() {
    let mut t = Table::new(
        "hotpath microbenchmarks (release)",
        &["path", "mean", "p50", "min"],
    );
    // separate table: throughput columns don't fit the latency headers
    let mut tp = Table::new(
        "batched decode attention — sequential vs parallel",
        &["config", "per-batch", "decode tok/s", "speedup"],
    );
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let have_artifacts = std::path::Path::new(dir).join("manifest.json").exists();
    let hw_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // --- CPU flash attention (offload host path) ----------------------
    for (heads, kv, d) in [(5usize, 4096usize, 128usize), (5, 16384, 128)] {
        let q = vec![0.01f32; heads * d];
        let k = vec![0.02f32; heads * kv * d];
        let v = vec![0.03f32; heads * kv * d];
        let mut out = vec![0.0f32; heads * d];
        let p = FlashParams::decode(heads, kv, d);
        let s = bench(3, 20, || flash_attention(&q, &k, &v, &mut out, &p));
        t.row(&[
            format!("flash_cpu decode kv={kv} heads={heads}"),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);
    }

    // --- batched decode attention: sequential vs parallel vs paged ----
    // The tentpole path: all sequences × all query heads of a decode
    // batch as one flat work queue.  Mistral-7B GQA (32 q heads / 8 KV
    // heads) at batch 8 — the ISSUE's ≥2× @ threads ≥ 4 criterion.
    // The paged rows gather identical data through a block table
    // (page_size 16) and must produce identical bits.
    let mut json_rows: Vec<(String, f64)> = Vec::new();
    {
        // ≥4 workers per the ISSUE criterion, capped at 8 to avoid
        // spawning one thread per core on large hosts; the row label
        // carries the count so undersized machines are visible.
        let threads = hw_threads.clamp(4, 8);
        let par_cfg = ParallelConfig { threads, min_work_per_thread: 0 };
        let page_size = 16;
        for (m, nseq, kv) in [(&MISTRAL_7B, 8usize, 2048usize), (&MISTRAL_7B, 16, 1024)] {
            let data = DecodeBatchData::synth(m, nseq, kv);
            let seqs = data.seqs();
            let (pool, tables) = data.paged(page_size);
            let paged_seqs = data.paged_seqs(&pool, &tables);
            let n_out = nseq * m.heads as usize * m.head_dim as usize;
            let mut out = vec![0.0f32; n_out];

            let seq_pool = WorkPool::new(ParallelConfig::sequential());
            let s1 = bench(2, 8, || {
                batch_decode_attention(&data.shape, &seqs, &mut out, &seq_pool)
            });
            let par_pool = WorkPool::new(par_cfg);
            let sn = bench(2, 8, || {
                batch_decode_attention(&data.shape, &seqs, &mut out, &par_pool)
            });
            // paged gather: bit-identical output, measured cost of the
            // page-table indirection
            let contig_out = out.clone();
            let sp = bench(2, 8, || {
                batch_decode_attention(&data.shape, &paged_seqs, &mut out, &par_pool)
            });
            assert_eq!(contig_out, out, "paged decode must be bit-identical");

            // decode-attention throughput: one generated token per
            // sequence per batch call.
            tp.row(&[
                format!("{} b={nseq} kv={kv} threads=1", m.name),
                fmt_time(s1.mean_s),
                rate(nseq as f64, s1.mean_s, "tok"),
                String::from("—"),
            ]);
            tp.row(&[
                format!("{} b={nseq} kv={kv} threads={threads}", m.name),
                fmt_time(sn.mean_s),
                rate(nseq as f64, sn.mean_s, "tok"),
                x(s1.mean_s / sn.mean_s),
            ]);
            tp.row(&[
                format!("{} b={nseq} kv={kv} paged ps={page_size} threads={threads}", m.name),
                fmt_time(sp.mean_s),
                rate(nseq as f64, sp.mean_s, "tok"),
                x(s1.mean_s / sp.mean_s),
            ]);
            json_rows.push((
                format!("{} b={nseq} kv={kv} sequential", m.name),
                s1.events_per_s(nseq as f64),
            ));
            json_rows.push((
                format!("{} b={nseq} kv={kv} parallel threads={threads}", m.name),
                sn.events_per_s(nseq as f64),
            ));
            json_rows.push((
                format!("{} b={nseq} kv={kv} paged ps={page_size} threads={threads}", m.name),
                sp.events_per_s(nseq as f64),
            ));
        }
    }

    // --- gather throughput: scalar rowwise vs blocked vs int8 fused ---
    // The codec tentpole measured in isolation: the same paged KV
    // walked (a) row-at-a-time through `KvView::row` — the pre-blocking
    // gather with its per-row page-index division and bounds checks —
    // (b) in page-contiguous runs (blocked f32; bit-identity asserted),
    // and (c) blocked with dequantization fused over int8 pages (~4×
    // fewer bytes through memory; tolerance asserted).  Rows land in
    // BENCH_decode.json as gathered KV rows per second.
    {
        let (heads, d, page_size) = (8usize, 128usize, 16usize);
        // 8192 leaves the last-level cache behind on most hosts — the
        // long-context regime where the blocked walk and the int8
        // bytes-through-memory saving actually pay.
        for kv in [512usize, 2048, 8192] {
            let cache = CacheShape { layers: 1, kv_heads: 1, max_seq: kv, head_dim: d };
            let mut rng = Rng::new(kv as u64);
            let rows_k: Vec<Vec<f32>> = (0..kv).map(|_| rng.f32_vec(d)).collect();
            let rows_v: Vec<Vec<f32>> = (0..kv).map(|_| rng.f32_vec(d)).collect();
            let q = rng.f32_vec(heads * d);
            let fill = |codec: PageCodec| {
                let mut pool = PagePool::with_codec(
                    page_size,
                    d,
                    BlockTable::pages_needed(cache, page_size, kv),
                    codec,
                );
                let mut t = BlockTable::new(cache, page_size);
                t.ensure_capacity(kv, &mut pool).expect("pool sized for kv");
                for r in 0..kv {
                    let (page, slot) = t.locate(0, 0, r);
                    pool.write_row(page, slot, &rows_k[r], &rows_v[r]);
                }
                (pool, t)
            };
            let (fpool, ftab) = fill(PageCodec::F32);
            let (qpool, qtab) = fill(PageCodec::Int8);
            let kf = KvView::Paged { store: fpool.k_store(), pages: ftab.layer_pages(0), page_size };
            let vf = KvView::Paged { store: fpool.v_store(), pages: ftab.layer_pages(0), page_size };
            let kq = KvView::PagedI8 {
                store: qpool.k_quant_store(),
                pages: qtab.layer_pages(0),
                page_size,
            };
            let vq = KvView::PagedI8 {
                store: qpool.v_quant_store(),
                pages: qtab.layer_pages(0),
                page_size,
            };
            let p = FlashParams::decode_gqa(heads, 1, kv, d);
            let mut out = vec![0.0f32; heads * d];

            let sr = bench(2, 12, || {
                flash_attention_view_rowwise(&q, &kf, &vf, &mut out, &p)
            });
            let rowwise_out = out.clone();
            let sb = bench(2, 12, || flash_attention_view(&q, &kf, &vf, &mut out, &p));
            assert_eq!(rowwise_out, out, "blocked f32 gather must be bit-identical at kv={kv}");
            let si = bench(2, 12, || flash_attention_view(&q, &kq, &vq, &mut out, &p));
            let err = out
                .iter()
                .zip(&rowwise_out)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err < 0.05, "int8 fused gather out of tolerance at kv={kv}: {err}");
            assert!(err > 0.0, "int8 fused gather suspiciously identical at kv={kv}");

            // every query head walks the single KV plane once per call
            let rows = (heads * kv) as f64;
            tp.row(&[
                format!("gather scalar f32 rowwise kv={kv}"),
                fmt_time(sr.mean_s),
                rate(rows, sr.mean_s, "row"),
                String::from("—"),
            ]);
            tp.row(&[
                format!("gather blocked f32 kv={kv}"),
                fmt_time(sb.mean_s),
                rate(rows, sb.mean_s, "row"),
                x(sr.mean_s / sb.mean_s),
            ]);
            tp.row(&[
                format!("gather int8 fused kv={kv}"),
                fmt_time(si.mean_s),
                rate(rows, si.mean_s, "row"),
                x(sr.mean_s / si.mean_s),
            ]);
            json_rows.push((
                format!("gather scalar f32 rowwise kv={kv}"),
                sr.events_per_s(rows),
            ));
            json_rows.push((format!("gather blocked f32 kv={kv}"), sb.events_per_s(rows)));
            json_rows.push((format!("gather int8 fused kv={kv}"), si.events_per_s(rows)));
        }
    }

    // --- engine end-to-end over the host-model backend ----------------
    // Always runs (no artifact bundle needed): TINY_GQA through the full
    // stack, sequential vs parallel decode, per-batch latency + tok/s.
    for threads in [1usize, 4] {
        let cfg = EngineConfig {
            parallel: ParallelConfig { threads, min_work_per_thread: 0 },
            ..EngineConfig::default()
        };
        let mut engine = Engine::with_backend(
            Box::new(HostModelBackend::new(HostModelConfig::for_shape(TINY_GQA, 128))),
            cfg,
        );
        let mut n = 0u64;
        let s = bench(1, 3, || {
            n += 1;
            for i in 0..8u64 {
                engine
                    .submit(
                        vec![((n * 7 + i) % 500) as i32 + 1; 12],
                        GenParams { max_new_tokens: 8, eos_token: None, share_prefix: false },
                    )
                    .unwrap();
            }
            let out = engine.run_until_idle().unwrap();
            assert_eq!(out.len(), 8);
        });
        let m = &engine.metrics;
        t.row(&[
            format!("host engine 8×(prefill12+8dec) threads={threads}"),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);
        tp.row(&[
            format!("host engine e2e threads={threads}"),
            fmt_time(m.decode_s / m.decode_steps.max(1) as f64),
            rate(m.decoded_tokens as f64, m.decode_s, "tok"),
            String::from("—"),
        ]);
        json_rows.push((
            format!(
                "host engine paged decode threads={threads} (occ peak {:.0}%)",
                m.peak_page_occupancy() * 100.0
            ),
            m.decoded_tokens as f64 / m.decode_s.max(1e-12),
        ));
    }

    // --- tiered paged KV: device-only vs cold-page host offload -------
    // The §4.4 cooperative strategy at page granularity: the same
    // workload served with the whole cache device-resident vs with the
    // device pool capped at several modeled capacities (cold pages
    // spill to the host tier over the modeled PCIe link).  Tokens must
    // be identical in every configuration; the tok/s delta is the
    // tiered-gather + migration cost.  Rows land in BENCH_offload.json.
    let mut offload_rows: Vec<(String, f64)> = Vec::new();
    {
        // tiny_gqa geometry: a block group is layers 2 × kv_heads 2 = 4
        // pages of 2·4·16·8 B = 1 KiB → 4 KiB per group.
        let group_bytes = 4 * 1024usize;
        let prompts: Vec<Vec<i32>> =
            (0..4).map(|i| vec![(i as i32) * 9 + 3; 24]).collect();
        let gp = GenParams { max_new_tokens: 24, eos_token: None, share_prefix: false };
        let run = |device_groups: usize, host_groups: usize| {
            let cfg = EngineConfig {
                parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
                kv_layout: KvLayout::Paged,
                device_kv_budget: device_groups * group_bytes,
                host_kv_budget: host_groups * group_bytes,
                page_size: 16,
                ..EngineConfig::default()
            };
            let mut e = Engine::with_backend(
                Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
                cfg,
            );
            for pr in &prompts {
                e.submit(pr.clone(), gp).unwrap();
            }
            let mut out = e.run_until_idle().unwrap();
            out.sort_by_key(|r| r.id);
            let toks: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
            (toks, e.metrics.clone())
        };

        // each request spans 24 + 24 = 48 tokens = 3 block groups; 16
        // groups hold the whole batch device-resident.
        let (base_toks, base_m) = run(16, 0);
        assert_eq!(base_m.pages_migrated, 0);
        offload_rows.push(("device-only dev=16 groups".into(), base_m.decode_tps()));
        tp.row(&[
            "tiered offload dev=16 groups (device-only)".into(),
            fmt_time(base_m.decode_s / base_m.decode_steps.max(1) as f64),
            rate(base_m.decoded_tokens as f64, base_m.decode_s, "tok"),
            String::from("—"),
        ]);
        for dg in [8usize, 6, 4] {
            let (toks, m) = run(dg, 12);
            assert_eq!(
                base_toks, toks,
                "tiered serving changed tokens at device={dg} groups"
            );
            offload_rows.push((
                format!(
                    "tiered dev={dg} groups host=12 (migrated {} pages in {} moves, \
                     pcie {:.1} µs, {} preemptions)",
                    m.pages_migrated,
                    m.migrations,
                    m.pcie_modeled_s * 1e6,
                    m.preemptions
                ),
                m.decode_tps(),
            ));
            tp.row(&[
                format!("tiered offload dev={dg} groups host=12"),
                fmt_time(m.decode_s / m.decode_steps.max(1) as f64),
                rate(m.decoded_tokens as f64, m.decode_s, "tok"),
                // same convention as the rows above: >1 means faster
                // than the device-only baseline
                x(m.decode_tps() / base_m.decode_tps().max(1e-12)),
            ]);
        }
    }

    // --- swap-out vs recompute preemption -----------------------------
    // The reclamation decision under device pressure: the same
    // over-committed workload served with victims recompute-preempted
    // (prompt replay) vs swap-out-preempted (block table parked on the
    // host tier, restored on resume).  Tokens must be identical in
    // every configuration (parity asserted); the end-to-end tok/s
    // delta is the replay work that swapping avoids.  Rows land in
    // BENCH_reclaim.json.
    let mut reclaim_rows: Vec<(String, f64)> = Vec::new();
    {
        // tiny_gqa geometry: 4 KiB per block group; each request spans
        // 8 + 40 = 48 tokens = 3 groups, so 6 requests want 18 groups.
        let group_bytes = 4 * 1024usize;
        let prompts: Vec<Vec<i32>> = (0..6).map(|i| vec![(i as i32) * 5 + 2; 8]).collect();
        let gp = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
        let run = |device_groups: usize, host_groups: usize, mode: PreemptMode| {
            let cfg = EngineConfig {
                parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
                kv_layout: KvLayout::Paged,
                device_kv_budget: device_groups * group_bytes,
                host_kv_budget: host_groups * group_bytes,
                page_size: 16,
                preempt_mode: mode,
                victim_policy: VictimPolicy::Youngest,
                ..EngineConfig::default()
            };
            let mut e = Engine::with_backend(
                Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
                cfg,
            );
            for pr in &prompts {
                e.submit(pr.clone(), gp).unwrap();
            }
            let mut out = e.run_until_idle().unwrap();
            out.sort_by_key(|r| r.id);
            let toks: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
            (toks, e.metrics.clone())
        };
        let (base_toks, base_m) = run(32, 0, PreemptMode::Recompute);
        assert_eq!(base_m.preemptions, 0, "unconstrained run never preempts");
        // end-to-end generated-token throughput: replay work inflates
        // prefill time, so decode-only tok/s would hide the cost.
        let e2e = |m: &fastattn::metrics::EngineMetrics| {
            m.decoded_tokens as f64 / (m.prefill_s + m.decode_s).max(1e-12)
        };
        reclaim_rows.push(("unconstrained dev=32 groups".into(), e2e(&base_m)));
        for dg in [3usize, 2] {
            let (rec_toks, rec_m) = run(dg, 4, PreemptMode::Recompute);
            let (swap_toks, swap_m) = run(dg, 4, PreemptMode::Swap);
            assert_eq!(base_toks, rec_toks, "recompute changed tokens at dev={dg}");
            assert_eq!(base_toks, swap_toks, "swap-out changed tokens at dev={dg}");
            assert!(
                swap_m.prefilled_tokens <= rec_m.prefilled_tokens,
                "swap-out must not replay more prefill than recompute"
            );
            reclaim_rows.push((
                format!(
                    "recompute dev={dg} groups host=4 ({} preemptions, replayed {} tok)",
                    rec_m.preemptions,
                    rec_m.prefilled_tokens - 48,
                ),
                e2e(&rec_m),
            ));
            reclaim_rows.push((
                format!(
                    "swap dev={dg} groups host=4 ({} swaps, {} promotions, avoided {} tok)",
                    swap_m.swaps_out, swap_m.promotions, swap_m.recompute_tokens_avoided,
                ),
                e2e(&swap_m),
            ));
            tp.row(&[
                format!("reclaim recompute dev={dg} host=4"),
                fmt_time(rec_m.decode_s / rec_m.decode_steps.max(1) as f64),
                rate(rec_m.decoded_tokens as f64, rec_m.prefill_s + rec_m.decode_s, "tok"),
                String::from("—"),
            ]);
            tp.row(&[
                format!("reclaim swap      dev={dg} host=4"),
                fmt_time(swap_m.decode_s / swap_m.decode_steps.max(1) as f64),
                rate(swap_m.decoded_tokens as f64, swap_m.prefill_s + swap_m.decode_s, "tok"),
                x(e2e(&swap_m) / e2e(&rec_m).max(1e-12)),
            ]);
        }
    }

    // --- shared-prefix KV pages: shared vs unshared -------------------
    // N requests carrying the same 32-token system prompt, served with
    // `share_prefix` off and on.  Tokens must be identical (parity
    // asserted); the deltas — prompt tokens actually prefilled, peak
    // pages, prefill/decode tok/s — are the value of prefix sharing.
    // Rows land in BENCH_prefix.json.
    let mut prefix_rows: Vec<(String, f64)> = Vec::new();
    {
        let system = vec![7i32; 32];
        let prompts: Vec<Vec<i32>> = (0..8)
            .map(|i| {
                let mut p = system.clone();
                p.extend(vec![i as i32 + 40; 6]);
                p
            })
            .collect();
        let run = |share: bool| {
            let cfg = EngineConfig {
                parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
                kv_layout: KvLayout::Paged,
                page_size: 16,
                ..EngineConfig::default()
            };
            let mut e = Engine::with_backend(
                Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
                cfg,
            );
            let gp = GenParams { max_new_tokens: 16, eos_token: None, share_prefix: share };
            for pr in &prompts {
                e.submit(pr.clone(), gp).unwrap();
            }
            let mut out = e.run_until_idle().unwrap();
            out.sort_by_key(|r| r.id);
            let toks: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
            (toks, e.metrics.clone())
        };
        let (base_toks, bm) = run(false);
        let (shared_toks, sm) = run(true);
        assert_eq!(base_toks, shared_toks, "prefix sharing must not change tokens");
        assert!(sm.prefix_hits > 0, "the common system prompt must hit");
        assert!(
            sm.prefilled_tokens < bm.prefilled_tokens,
            "sharing must skip prefill work"
        );
        tp.row(&[
            format!("prefix unshared 8×(sys32+sfx6): prefilled {} tok", bm.prefilled_tokens),
            fmt_time(bm.prefill_s / bm.chunk_steps.max(1) as f64),
            rate(bm.prefilled_tokens as f64, bm.prefill_s, "tok"),
            String::from("—"),
        ]);
        tp.row(&[
            format!(
                "prefix shared   8×(sys32+sfx6): prefilled {} tok ({} hits, {} cow)",
                sm.prefilled_tokens, sm.prefix_hits, sm.cow_splits
            ),
            fmt_time(sm.prefill_s / sm.chunk_steps.max(1) as f64),
            rate(sm.prefilled_tokens as f64, sm.prefill_s, "tok"),
            x(bm.prefill_s / sm.prefill_s.max(1e-12)),
        ]);
        prefix_rows.push(("unshared prefill tok/s".into(), bm.prefill_tps()));
        prefix_rows.push((
            format!(
                "shared prefill tok/s (hits {}, saved {} tok, cow {}, shared pages {})",
                sm.prefix_hits, sm.prefix_tokens_saved, sm.cow_splits, sm.shared_pages
            ),
            sm.prefill_tps(),
        ));
        prefix_rows.push(("unshared decode tok/s".into(), bm.decode_tps()));
        prefix_rows.push(("shared decode tok/s".into(), sm.decode_tps()));
        prefix_rows.push(("unshared peak pages".into(), bm.peak_pages_used as f64));
        prefix_rows.push(("shared peak pages".into(), sm.peak_pages_used as f64));
    }

    // --- cascade decode over shared-prefix pages: batch scaling -------
    // The two-phase cascade at adopter counts 1 → 64: every request
    // carries the same 32-token system prompt (two shared page-16
    // blocks), served with `share_prefix` on in both arms and `cascade`
    // off vs on.  Tokens must be bit-identical (parity asserted); the
    // shared-KV bytes gathered per decode step stay **flat** in the
    // adopter count under cascade — one multi-query pass per group —
    // while growing linearly without, and every byte the cascade skips
    // is accounted: gathered(cascade) + saved == gathered(baseline).
    // Rows land in BENCH_prefix.json.
    {
        let system = vec![7i32; 32];
        let page_size = 16usize;
        // tiny_gqa head_dim; the engine subtracts saved rows at this rate
        let row_bytes = PageCodec::F32.row_bytes(8) as u64;
        let mut per_extra: Vec<f64> = Vec::new();
        for adopters in [1usize, 4, 16, 64] {
            let prompts: Vec<Vec<i32>> = (0..adopters)
                .map(|i| {
                    let mut p = system.clone();
                    p.extend([(i % 24) as i32 + 40, (i / 24) as i32 + 8]);
                    p
                })
                .collect();
            let run = |cascade: bool| {
                let cfg = EngineConfig {
                    parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
                    kv_layout: KvLayout::Paged,
                    page_size,
                    // admit the whole cohort before any decode so every
                    // step batches all adopters into one cascade group
                    policy: Policy::PrefillFirst,
                    max_active: adopters.max(16),
                    cascade,
                    ..EngineConfig::default()
                };
                // whole-batch decode buckets, and the kernel KV tile
                // shrunk to the page size so the 32-token prefix is two
                // cascade tiles
                let host = HostModelConfig {
                    buckets: BucketGrid {
                        prefill_batches: vec![1, 4],
                        prefill_seqs: vec![8, 16, 32, 64],
                        decode_batches: vec![1, 4, 16, 64],
                    },
                    ..HostModelConfig::tiny_gqa().with_block_kv(page_size)
                };
                let mut e = Engine::with_backend(Box::new(HostModelBackend::new(host)), cfg);
                let gp = GenParams { max_new_tokens: 8, eos_token: None, share_prefix: true };
                for pr in &prompts {
                    e.submit(pr.clone(), gp).unwrap();
                }
                let mut out = e.run_until_idle().unwrap();
                out.sort_by_key(|r| r.id);
                let toks: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
                (toks, e.metrics.clone())
            };
            let (base_toks, bm) = run(false);
            let (casc_toks, cm) = run(true);
            assert_eq!(base_toks, casc_toks, "cascade changed tokens at b={adopters}");
            assert_eq!(bm.cascade_passes, 0, "cascade off must never take the cascade path");
            let saved = cm.shared_rows_saved * row_bytes;
            assert_eq!(
                cm.kv_bytes_gathered + saved,
                bm.kv_bytes_gathered,
                "cascade gather accounting must explain every saved byte at b={adopters}"
            );
            let steps = cm.decode_steps.max(1) as f64;
            if adopters == 1 {
                assert_eq!(cm.cascade_passes, 0, "a lone request has nothing to cascade with");
                assert_eq!(saved, 0, "no second adopter, no saved gather");
            } else {
                assert!(cm.cascade_passes > 0, "b={adopters} must take the cascade path");
                assert!(saved > 0, "b={adopters} must skip repeat shared gathers");
                per_extra.push(saved as f64 / steps / (adopters - 1) as f64);
            }
            prefix_rows.push((
                format!("cascade off b={adopters} kv bytes gathered/step"),
                bm.kv_bytes_gathered as f64 / bm.decode_steps.max(1) as f64,
            ));
            prefix_rows.push((
                format!(
                    "cascade on  b={adopters} kv bytes gathered/step ({} passes)",
                    cm.cascade_passes
                ),
                cm.kv_bytes_gathered as f64 / steps,
            ));
            prefix_rows.push((
                format!("cascade b={adopters} shared bytes saved/step"),
                saved as f64 / steps,
            ));
            tp.row(&[
                format!("cascade decode b={adopters} sys32 ps={page_size}"),
                fmt_time(cm.decode_s / cm.decode_steps.max(1) as f64),
                rate(cm.decoded_tokens as f64, cm.decode_s, "tok"),
                x(bm.kv_bytes_gathered as f64 / cm.kv_bytes_gathered.max(1) as f64),
            ]);
        }
        // the flatness claim: each extra adopter saves exactly one
        // shared-prefix gather per step, so saved/(b−1) — the per-step
        // shared cost — is the same at every batch size
        let c0 = per_extra[0];
        for &c in &per_extra {
            assert!(
                (c - c0).abs() <= 0.25 * c0,
                "shared gather per extra adopter must stay flat: {per_extra:?}"
            );
        }
    }

    // --- KV pack (continuous-batching memcpy boundary) ----------------
    {
        let shape = CacheShape { layers: 4, kv_heads: 4, max_seq: 160, head_dim: 64 };
        let seq: Vec<f32> = vec![1.0; shape.seq_elems()];
        let seqs: Vec<(usize, &[f32])> =
            (0..4).map(|i| (i, seq.as_slice())).collect();
        let s = bench(3, 50, || {
            let _ = pack_batch(shape, 4, &seqs).unwrap();
        });
        t.row(&[
            "kv pack_batch b=4 (tiny model)".into(),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);
    }

    // --- threaded ring AllReduce --------------------------------------
    for elems in [64 * 1024usize, 1024 * 1024] {
        let s = bench(1, 10, || {
            let shards: Vec<Vec<f32>> = (0..4).map(|r| vec![r as f32; elems]).collect();
            let _ = ring_all_reduce(shards);
        });
        t.row(&[
            format!("ring_all_reduce n=4 {}K f32", elems / 1024),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);
    }

    // --- PJRT execution paths ------------------------------------------
    if have_artifacts {
        let rt = Runtime::load(dir).expect("runtime");
        let mk = |salt: f32| {
            let n = 4 * 128 * 64;
            HostTensor::f32(
                vec![1, 4, 128, 64],
                (0..n).map(|i| ((i as f32 * 0.11 + salt).sin()) * 0.3).collect(),
            )
        };
        let (q, k, v) = (mk(0.0), mk(1.0), mk(2.0));
        let s = bench(2, 15, || {
            let _ = rt
                .run("kernel_fastattn_causal", &[q.clone(), k.clone(), v.clone()])
                .unwrap();
        });
        t.row(&[
            "pjrt kernel_fastattn_causal (1,4,128,64)".into(),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);
        let s = bench(2, 15, || {
            let _ = rt
                .run("kernel_standard_causal", &[q.clone(), k.clone(), v.clone()])
                .unwrap();
        });
        t.row(&[
            "pjrt kernel_standard_causal (1,4,128,64)".into(),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);

        // --- engine end-to-end: prefill + decode steps -----------------
        let rt2 = Runtime::load(dir).expect("runtime");
        let mut engine = Engine::new(rt2, EngineConfig::default());
        let mut n = 0u64;
        let s = bench(1, 5, || {
            n += 1;
            for i in 0..4 {
                engine
                    .submit(
                        vec![((n * 7 + i) % 500) as i32 + 1; 16],
                        GenParams { max_new_tokens: 8, eos_token: None, share_prefix: false },
                    )
                    .unwrap();
            }
            let out = engine.run_until_idle().unwrap();
            assert_eq!(out.len(), 4);
        });
        t.row(&[
            "engine 4 reqs × (prefill16 + 8 decode)".into(),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);
        let m = &engine.metrics;
        t.row(&[
            "engine decode step (amortized)".into(),
            fmt_time(m.decode_s / m.decode_steps.max(1) as f64),
            String::from("—"),
            String::from("—"),
        ]);
        t.row(&[
            "engine prefill step (amortized)".into(),
            fmt_time(m.prefill_s / m.prefill_steps.max(1) as f64),
            String::from("—"),
            String::from("—"),
        ]);
    } else {
        t.row(&[
            "pjrt/engine paths skipped (run `make artifacts`)".into(),
            "—".into(),
            "—".into(),
            "—".into(),
        ]);
    }

    t.print();
    tp.print();

    // machine-readable decode throughput for cross-PR comparison
    let json_path = std::path::Path::new("BENCH_decode.json");
    match write_bench_json(json_path, "decode", "tok/s", &json_rows) {
        Ok(()) => println!("\nwrote {} ({} rows)", json_path.display(), json_rows.len()),
        Err(e) => eprintln!("\nBENCH_decode.json not written: {e}"),
    }

    // device-only vs tiered throughput at the modeled device capacities
    let offload_path = std::path::Path::new("BENCH_offload.json");
    match write_bench_json(offload_path, "offload", "tok/s", &offload_rows) {
        Ok(()) => println!("wrote {} ({} rows)", offload_path.display(), offload_rows.len()),
        Err(e) => eprintln!("BENCH_offload.json not written: {e}"),
    }

    // shared vs unshared prefix serving (token parity asserted above)
    let prefix_path = std::path::Path::new("BENCH_prefix.json");
    match write_bench_json(prefix_path, "prefix", "tok/s", &prefix_rows) {
        Ok(()) => println!("wrote {} ({} rows)", prefix_path.display(), prefix_rows.len()),
        Err(e) => eprintln!("BENCH_prefix.json not written: {e}"),
    }

    // swap-out vs recompute preemption (token parity asserted above)
    let reclaim_path = std::path::Path::new("BENCH_reclaim.json");
    match write_bench_json(reclaim_path, "reclaim", "tok/s", &reclaim_rows) {
        Ok(()) => println!("wrote {} ({} rows)", reclaim_path.display(), reclaim_rows.len()),
        Err(e) => eprintln!("BENCH_reclaim.json not written: {e}"),
    }
}
