//! Hot-path microbenchmarks — the perf-pass instrument (EXPERIMENTS.md
//! §Perf).  Measures the real execution-layer costs:
//!
//!   * PJRT artifact execution (standalone kernel, prefill, decode)
//!   * engine decode step end-to-end (pack → execute → unpack → sample)
//!   * KV-cache batch pack/unpack memcpy
//!   * the rust CPU FlashAttention2 kernel (offload host path)
//!   * the threaded ring AllReduce
//!
//! Run with `cargo bench --bench hotpath` (release profile).

use fastattn::attention::flash::{flash_attention, FlashParams};
use fastattn::benchkit::{bench, fmt_time, Table};
use fastattn::coordinator::allreduce::ring_all_reduce;
use fastattn::coordinator::kv_cache::{pack_batch, CacheShape};
use fastattn::coordinator::{Engine, EngineConfig, GenParams};
use fastattn::runtime::{HostTensor, Runtime};

fn main() {
    let mut t = Table::new(
        "hotpath microbenchmarks (release)",
        &["path", "mean", "p50", "min"],
    );
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let have_artifacts = std::path::Path::new(dir).join("manifest.json").exists();

    // --- CPU flash attention (offload host path) ----------------------
    for (heads, kv, d) in [(5usize, 4096usize, 128usize), (5, 16384, 128)] {
        let q = vec![0.01f32; heads * d];
        let k = vec![0.02f32; heads * kv * d];
        let v = vec![0.03f32; heads * kv * d];
        let mut out = vec![0.0f32; heads * d];
        let p = FlashParams::decode(heads, kv, d);
        let s = bench(3, 20, || flash_attention(&q, &k, &v, &mut out, &p));
        t.row(&[
            format!("flash_cpu decode kv={kv} heads={heads}"),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);
    }

    // --- KV pack (continuous-batching memcpy boundary) ----------------
    {
        let shape = CacheShape { layers: 4, kv_heads: 4, max_seq: 160, head_dim: 64 };
        let seq: Vec<f32> = vec![1.0; shape.seq_elems()];
        let seqs: Vec<(usize, &[f32])> =
            (0..4).map(|i| (i, seq.as_slice())).collect();
        let s = bench(3, 50, || {
            let _ = pack_batch(shape, 4, &seqs).unwrap();
        });
        t.row(&[
            "kv pack_batch b=4 (tiny model)".into(),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);
    }

    // --- threaded ring AllReduce --------------------------------------
    for elems in [64 * 1024usize, 1024 * 1024] {
        let s = bench(1, 10, || {
            let shards: Vec<Vec<f32>> = (0..4).map(|r| vec![r as f32; elems]).collect();
            let _ = ring_all_reduce(shards);
        });
        t.row(&[
            format!("ring_all_reduce n=4 {}K f32", elems / 1024),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);
    }

    // --- PJRT execution paths ------------------------------------------
    if have_artifacts {
        let rt = Runtime::load(dir).expect("runtime");
        let mk = |salt: f32| {
            let n = 4 * 128 * 64;
            HostTensor::f32(
                vec![1, 4, 128, 64],
                (0..n).map(|i| ((i as f32 * 0.11 + salt).sin()) * 0.3).collect(),
            )
        };
        let (q, k, v) = (mk(0.0), mk(1.0), mk(2.0));
        let s = bench(2, 15, || {
            let _ = rt
                .run("kernel_fastattn_causal", &[q.clone(), k.clone(), v.clone()])
                .unwrap();
        });
        t.row(&[
            "pjrt kernel_fastattn_causal (1,4,128,64)".into(),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);
        let s = bench(2, 15, || {
            let _ = rt
                .run("kernel_standard_causal", &[q.clone(), k.clone(), v.clone()])
                .unwrap();
        });
        t.row(&[
            "pjrt kernel_standard_causal (1,4,128,64)".into(),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);

        // --- engine end-to-end: prefill + decode steps -----------------
        let rt2 = Runtime::load(dir).expect("runtime");
        let mut engine = Engine::new(rt2, EngineConfig::default());
        let mut n = 0u64;
        let s = bench(1, 5, || {
            n += 1;
            for i in 0..4 {
                engine
                    .submit(
                        vec![((n * 7 + i) % 500) as i32 + 1; 16],
                        GenParams { max_new_tokens: 8, eos_token: None },
                    )
                    .unwrap();
            }
            let out = engine.run_until_idle().unwrap();
            assert_eq!(out.len(), 4);
        });
        t.row(&[
            "engine 4 reqs × (prefill16 + 8 decode)".into(),
            fmt_time(s.mean_s),
            fmt_time(s.p50_s),
            fmt_time(s.min_s),
        ]);
        let m = &engine.metrics;
        t.row(&[
            "engine decode step (amortized)".into(),
            fmt_time(m.decode_s / m.decode_steps.max(1) as f64),
            String::from("—"),
            String::from("—"),
        ]);
        t.row(&[
            "engine prefill step (amortized)".into(),
            fmt_time(m.prefill_s / m.prefill_steps.max(1) as f64),
            String::from("—"),
            String::from("—"),
        ]);
    } else {
        t.row(&[
            "pjrt/engine paths skipped (run `make artifacts`)".into(),
            "—".into(),
            "—".into(),
            "—".into(),
        ]);
    }

    t.print();
}
