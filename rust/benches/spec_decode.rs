//! Speculative decoding end-to-end: draft–verify vs vanilla greedy.
//!
//! Serves the same request sets through the host-model engine at draft
//! depths k ∈ {0, 2, 4, 8} on two workloads:
//!
//!   * **repetitive** prompts (short periodic phrases) — the
//!     prompt-lookup drafter's home turf: greedy decode settles into
//!     cycles the drafter rides, so one verify step emits several
//!     tokens at once
//!   * **non-repetitive** prompts (uniform random tokens) — the
//!     drafter mostly misses and every rejected draft page rolls back
//!
//! Token parity with the k = 0 baseline is asserted for every
//! configuration (speculation must be a pure perf transform), rollback
//! never exceeds what was speculatively written, and the accept-length
//! histogram must explain every decoded token.  Rows land in
//! `BENCH_spec.json`: accepted tokens per verify step, end-to-end
//! generated tok/s, and the step-count + wall-clock speedups over the
//! k = 0 baseline.  On a device where one (k+1)-row verify pass costs
//! about one decode pass — the memory-bound regime FastAttention
//! targets — the step-count speedup is the modeled end-to-end win; the
//! wall-clock column is what this CPU host model actually measured,
//! which charges every verify row at full price.
//!
//! Run with `cargo bench --bench spec_decode`; set `FASTATTN_SMOKE=1`
//! for the CI-sized sweep.

use std::path::Path;
use std::time::Instant;

use fastattn::attention::batch::ParallelConfig;
use fastattn::benchkit::{rate, write_bench_json, x, Table};
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout,
};
use fastattn::metrics::EngineMetrics;

/// Minimal deterministic LCG so the non-repetitive workload is
/// reproducible without an RNG dependency.
struct Lcg(u64);

impl Lcg {
    fn next_u64(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

/// Mean accepted tokens per verify step from the accept-length
/// histogram; a vanilla engine records no verify steps and emits
/// exactly one token per decode step.
fn mean_accept(m: &EngineMetrics) -> f64 {
    let steps: u64 = m.accept_len_hist.iter().sum();
    if steps == 0 {
        return 1.0;
    }
    let toks: u64 = m
        .accept_len_hist
        .iter()
        .enumerate()
        .map(|(i, &c)| (i as u64 + 1) * c)
        .sum();
    toks as f64 / steps as f64
}

fn main() {
    let smoke = std::env::var("FASTATTN_SMOKE").is_ok() || cfg!(debug_assertions);
    let (nreq, max_new, iters) = if smoke { (3usize, 16usize, 2usize) } else { (4, 48, 5) };

    // period-3 phrases, one offset per request so block tables diverge
    let repetitive: Vec<Vec<i32>> = (0..nreq)
        .map(|i| (0..24).map(|t| (t % 3) as i32 + 5 + 2 * i as i32).collect())
        .collect();
    let mut lcg = Lcg(0x5eed);
    let random: Vec<Vec<i32>> = (0..nreq)
        .map(|_| (0..24).map(|_| (lcg.next_u64() % 63) as i32 + 1).collect())
        .collect();

    // one serving run: deterministic tokens/metrics, best-of-`iters`
    // wall clock
    let run = |prompts: &[Vec<i32>], speculate: usize| {
        let mut best_wall = f64::INFINITY;
        let mut last = None;
        for _ in 0..iters {
            let cfg = EngineConfig {
                parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
                kv_layout: KvLayout::Paged,
                page_size: 4,
                speculate,
                ..EngineConfig::default()
            };
            let mut e = Engine::with_backend(
                Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
                cfg,
            );
            let gp = GenParams { max_new_tokens: max_new, eos_token: None, share_prefix: false };
            for pr in prompts {
                e.submit(pr.clone(), gp).unwrap();
            }
            let t0 = Instant::now();
            let mut out = e.run_until_idle().unwrap();
            best_wall = best_wall.min(t0.elapsed().as_secs_f64());
            out.sort_by_key(|r| r.id);
            let toks: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
            last = Some((toks, e.metrics.clone()));
        }
        let (toks, m) = last.expect("at least one iteration");
        (toks, m, best_wall)
    };

    let mut t = Table::new(
        "speculative decode — draft–verify vs vanilla greedy",
        &[
            "workload",
            "k",
            "accept tok/step",
            "e2e tok/s",
            "steps",
            "speedup(step)",
            "speedup(wall)",
        ],
    );
    let mut rows: Vec<(String, f64)> = Vec::new();
    let mut best_accept = 0.0f64;
    let mut best_step_speedup = 0.0f64;
    for (name, prompts) in [("repetitive", &repetitive), ("non-repetitive", &random)] {
        let (base_toks, base_m, base_wall) = run(prompts, 0);
        assert_eq!(base_m.draft_proposed, 0, "vanilla engine must never draft");
        assert_eq!(base_m.spec_pages_written, 0, "vanilla engine writes no draft pages");
        let generated: f64 = base_toks.iter().map(|toks| toks.len()).sum::<usize>() as f64;
        t.row(&[
            name.into(),
            "0".into(),
            "1.00".into(),
            rate(generated, base_wall, "tok"),
            base_m.decode_steps.to_string(),
            "—".into(),
            "—".into(),
        ]);
        rows.push((format!("{name} k=0 accepted tok/step"), 1.0));
        rows.push((format!("{name} k=0 e2e tok/s"), generated / base_wall.max(1e-12)));
        for k in [2usize, 4, 8] {
            let (toks, m, wall) = run(prompts, k);
            assert_eq!(base_toks, toks, "speculation changed tokens ({name} k={k})");
            assert!(
                m.spec_rollback_pages <= m.spec_pages_written,
                "rolled back {} of {} draft pages ({name} k={k})",
                m.spec_rollback_pages,
                m.spec_pages_written
            );
            let hist_tokens: u64 = m
                .accept_len_hist
                .iter()
                .enumerate()
                .map(|(i, &c)| (i as u64 + 1) * c)
                .sum();
            assert_eq!(
                hist_tokens, m.decoded_tokens,
                "accept histogram must explain every decoded token ({name} k={k})"
            );
            let accept = mean_accept(&m);
            let step_speedup = base_m.decode_steps as f64 / m.decode_steps.max(1) as f64;
            let wall_speedup = base_wall / wall.max(1e-12);
            if name == "repetitive" {
                best_accept = best_accept.max(accept);
                best_step_speedup = best_step_speedup.max(step_speedup);
            }
            t.row(&[
                name.into(),
                k.to_string(),
                format!("{accept:.2}"),
                rate(generated, wall, "tok"),
                m.decode_steps.to_string(),
                x(step_speedup),
                x(wall_speedup),
            ]);
            rows.push((format!("{name} k={k} accepted tok/step"), accept));
            rows.push((format!("{name} k={k} e2e tok/s"), generated / wall.max(1e-12)));
            rows.push((format!("{name} k={k} speedup vs k=0 (verify steps)"), step_speedup));
            rows.push((format!("{name} k={k} speedup vs k=0 (wall)"), wall_speedup));
        }
    }
    // the headline: on prompts the drafter can read, some depth must
    // beat one-token-per-step — and fewer steps is the modeled win
    assert!(
        best_accept > 1.0,
        "repetitive prompts never beat 1 accepted token/step (best {best_accept:.2})"
    );
    assert!(
        best_step_speedup > 1.0,
        "speculation never reduced decode steps on repetitive prompts"
    );
    t.print();

    let json_path = Path::new("BENCH_spec.json");
    match write_bench_json(json_path, "spec", "tok/s", &rows) {
        Ok(()) => println!("\nwrote {} ({} rows)", json_path.display(), rows.len()),
        Err(e) => eprintln!("\nBENCH_spec.json not written: {e}"),
    }
}
