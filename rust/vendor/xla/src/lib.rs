//! Offline stand-in for the `xla-rs` PJRT bindings.
//!
//! This environment has no libxla / PJRT plugin, so the real binding
//! crate cannot build here.  This stand-in keeps the same API surface the
//! serving runtime uses so the crate compiles and the pure-host paths
//! (tensors, literals) work for real; anything that would need the
//! actual PJRT runtime ([`PjRtClient::cpu`] onward) returns
//! [`Error::BackendUnavailable`], and every artifact-dependent test
//! self-skips on the missing `artifacts/manifest.json`.
//!
//! [`Literal`] is a genuine host-side implementation (typed storage +
//! dims + reshape), because `HostTensor` round-trips through it in unit
//! tests that run everywhere.

use std::borrow::Borrow;
use std::fmt;

/// Errors surfaced by the stand-in binding.
#[derive(Debug)]
pub enum Error {
    /// The real PJRT backend is not linked into this build.
    BackendUnavailable(&'static str),
    /// Host-side literal misuse (shape/type mismatch).
    Literal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::BackendUnavailable(what) => write!(
                f,
                "xla backend unavailable in this build ({what}); \
                 artifact execution requires the real xla-rs binding"
            ),
            Error::Literal(msg) => write!(f, "literal error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

/// Crate-local result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types a literal can hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    F32,
    S32,
    F16,
    F64,
    Pred,
}

/// Host types storable in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn store(data: &[Self]) -> Storage;
    fn load(storage: &Storage) -> Option<Vec<Self>>;
}

/// Typed backing storage of a literal.
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn store(data: &[Self]) -> Storage {
        Storage::F32(data.to_vec())
    }
    fn load(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn store(data: &[Self]) -> Storage {
        Storage::I32(data.to_vec())
    }
    fn load(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Dimensions + element type of an array literal.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host-side literal: typed data + dims (tuples hold nested literals).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { storage: T::store(data), dims: vec![data.len() as i64] }
    }

    /// A tuple literal wrapping `parts`.
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { storage: Storage::Tuple(parts), dims: vec![] }
    }

    fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(_) => 0,
        }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error::Literal(format!(
                "reshape to {dims:?} ({want} elems) from {have} elems"
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Shape of an array literal; errors on tuples.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.storage {
            Storage::F32(_) => ElementType::F32,
            Storage::I32(_) => ElementType::S32,
            Storage::Tuple(_) => {
                return Err(Error::Literal("tuple literal has no array shape".into()))
            }
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    /// Copy the data out as `Vec<T>`; errors on element-type mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.storage).ok_or_else(|| {
            Error::Literal(format!("literal is not {:?}", T::TY))
        })
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(parts) => Ok(parts),
            _ => Err(Error::Literal("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (opaque in the stand-in).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::BackendUnavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation handle (opaque in the stand-in).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// A device buffer handle.  Never constructible in the stand-in.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::BackendUnavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable handle.  Never constructible in the stand-in.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b<T: Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// The PJRT client.  [`PjRtClient::cpu`] fails in the stand-in, which is
/// the single choke point that keeps every artifact path unreachable.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::BackendUnavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::BackendUnavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::BackendUnavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_reshape_to_rank0() {
        let lit = Literal::vec1(&[42i32]);
        let r = lit.reshape(&[]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[] as &[i64]);
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![42]);
    }

    #[test]
    fn reshape_rejects_bad_count() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert!(lit.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        assert!(t.array_shape().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1.0f32]).to_tuple().is_err());
    }

    #[test]
    fn backend_is_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("unavailable"));
    }
}
