//! Offline stand-in for the `anyhow` crate.
//!
//! The registry is unreachable in this environment, so this vendored
//! crate provides the exact API subset `fastattn` uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` /
//! `bail!` macros.  Semantics follow the real crate where it matters:
//!
//! * `Error` does **not** implement `std::error::Error`, which is what
//!   makes the blanket `From<E: std::error::Error>` conversion possible
//!   on stable (the same trick the real crate uses);
//! * `.context(..)` / `.with_context(..)` prepend a message, and the
//!   chain renders outermost-first with `: ` separators for both `{}`
//!   and `{:#}` formatting.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error value: a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from anything displayable (what `anyhow!(expr)` expands to).
    pub fn from_display<D: fmt::Display>(d: D) -> Self {
        Error { chain: vec![d.to_string()] }
    }

    /// Build from a message (mirrors `anyhow::Error::msg`).
    pub fn msg<D: fmt::Display>(d: D) -> Self {
        Self::from_display(d)
    }

    /// Prepend a context message.
    pub fn context<D: fmt::Display>(mut self, ctx: D) -> Self {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// The blanket conversion every `?` relies on.  `Error` itself must not
// implement `std::error::Error` or this would conflict with the
// reflexive `From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Render the source chain eagerly; the stand-in stores strings.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    /// Wrap the error with a context message.
    fn context<D: fmt::Display>(self, ctx: D) -> Result<T>;
    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<D: fmt::Display>(self, ctx: D) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<D: fmt::Display>(self, ctx: D) -> Result<T> {
        self.ok_or_else(|| Error::from_display(ctx))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::from_display(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::from_display(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::from_display(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::from_display($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($tt:tt)*) => {
        if !$cond {
            return ::core::result::Result::Err($crate::anyhow!($($tt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing file"));
    }

    #[test]
    fn context_prepends_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading manifest").unwrap_err();
        let s = format!("{e}");
        assert!(s.starts_with("loading manifest"), "{s}");
        assert!(s.contains("missing file"), "{s}");
        // alternate formatting renders the same chain
        assert_eq!(s, format!("{e:#}"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("thing absent").unwrap_err();
        assert_eq!(e.to_string(), "thing absent");
        let w: Option<u32> = Some(7);
        assert_eq!(w.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                bail!("three is right out (n={})", n);
            }
            Err(anyhow!(String::from("plain string error")))
        }
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
        assert!(f(1).unwrap_err().to_string().contains("plain string"));
    }

    #[test]
    fn anyhow_error_recontexts() {
        fn inner() -> Result<()> {
            Err(anyhow!("inner failure"))
        }
        let e = inner().context("outer step").unwrap_err();
        assert_eq!(e.to_string(), "outer step: inner failure");
    }
}
